//! Batched zero-shot prediction server.
//!
//! Serving is where the paper's eq. (5) shortcut pays off operationally: a
//! request carries *novel* vertices (features never seen in training) plus
//! the edges to score. The server batches concurrently queued requests into
//! one prediction call — the generalized vec trick's cost
//! `O(min(v‖a‖₀ + m·t, u‖a‖₀ + q·t))` amortizes the `‖a‖₀` term across the
//! whole batch, so batching improves throughput exactly as dynamic batching
//! does in model-serving systems.
//!
//! Architecture: submitters push [`PredictRequest`]s onto an MPSC channel; a
//! worker thread drains whatever is queued (up to `max_batch_edges`), merges
//! it into one [`Dataset`], predicts once, and scatters replies.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::data::Dataset;
use crate::linalg::Matrix;
use crate::model::DualModel;

/// One prediction request: a private bipartite graph (novel vertices +
/// edges) to score against the trained model.
pub struct PredictRequest {
    /// Start-vertex feature rows (u × d, flattened row-major).
    pub start_features: Vec<Vec<f64>>,
    /// End-vertex feature rows (v × r).
    pub end_features: Vec<Vec<f64>>,
    /// Edges as (start_row, end_row) into the request's own vertex lists.
    pub edges: Vec<(u32, u32)>,
    /// Reply channel for the scores (one per edge, in order).
    pub reply: Sender<Vec<f64>>,
}

/// Server configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServerConfig {
    /// Edge budget per merged batch.
    pub max_batch_edges: usize,
    /// Worker threads per batched prediction matvec (`0` = all cores,
    /// `1` = serial). The trained model is shared, not copied — the GVT
    /// operators are `Sync`, so sharding a batch costs no extra memory.
    pub threads: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch_edges: 65_536, threads: 1 }
    }
}

/// Running counters.
#[derive(Debug, Default)]
pub struct ServerStats {
    /// Requests answered.
    pub requests: AtomicUsize,
    /// Merged batches executed.
    pub batches: AtomicUsize,
    /// Total edges scored.
    pub edges_scored: AtomicUsize,
}

/// Handle to a running prediction server.
pub struct PredictServer {
    tx: Option<Sender<PredictRequest>>,
    worker: Option<JoinHandle<()>>,
    stats: Arc<ServerStats>,
}

impl PredictServer {
    /// Spawn the worker thread around a trained model.
    pub fn start(model: DualModel, cfg: ServerConfig) -> PredictServer {
        let (tx, rx) = channel::<PredictRequest>();
        let stats = Arc::new(ServerStats::default());
        let worker_stats = stats.clone();
        let worker = std::thread::spawn(move || worker_loop(model, cfg, rx, worker_stats));
        PredictServer { tx: Some(tx), worker: Some(worker), stats }
    }

    /// Sender handle for asynchronous submission from other threads.
    ///
    /// NOTE: every clone must be dropped before [`PredictServer::shutdown`]
    /// can complete — the worker exits when all senders disconnect.
    pub fn sender(&self) -> Sender<PredictRequest> {
        self.tx.as_ref().expect("server running").clone()
    }

    /// Convenience: submit one request and block for its scores.
    pub fn predict_blocking(
        &self,
        start_features: Vec<Vec<f64>>,
        end_features: Vec<Vec<f64>>,
        edges: Vec<(u32, u32)>,
    ) -> Result<Vec<f64>, String> {
        let (reply_tx, reply_rx) = channel();
        self.tx
            .as_ref()
            .expect("server running")
            .send(PredictRequest { start_features, end_features, edges, reply: reply_tx })
            .map_err(|_| "server stopped".to_string())?;
        reply_rx.recv().map_err(|_| "server dropped request".to_string())
    }

    /// Observability counters.
    pub fn stats(&self) -> &ServerStats {
        &self.stats
    }

    /// Graceful shutdown: waits for queued work to finish.
    pub fn shutdown(mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

impl Drop for PredictServer {
    fn drop(&mut self) {
        drop(self.tx.take());
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

fn worker_loop(
    model: DualModel,
    cfg: ServerConfig,
    rx: Receiver<PredictRequest>,
    stats: Arc<ServerStats>,
) {
    loop {
        // Block for the first request of the batch.
        let first = match rx.recv() {
            Ok(req) => req,
            Err(_) => return, // all senders gone
        };
        let mut batch = vec![first];
        let mut edge_count = batch[0].edges.len();
        // Greedily drain whatever else is queued (dynamic batching).
        while edge_count < cfg.max_batch_edges {
            match rx.try_recv() {
                Ok(req) => {
                    edge_count += req.edges.len();
                    batch.push(req);
                }
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
        serve_batch(&model, batch, &stats, cfg.threads);
    }
}

fn serve_batch(model: &DualModel, batch: Vec<PredictRequest>, stats: &ServerStats, threads: usize) {
    // Merge requests into one dataset with offset vertex indices.
    let d = model.train_start_features.cols();
    let r = model.train_end_features.cols();
    let total_starts: usize = batch.iter().map(|b| b.start_features.len()).sum();
    let total_ends: usize = batch.iter().map(|b| b.end_features.len()).sum();
    let total_edges: usize = batch.iter().map(|b| b.edges.len()).sum();

    let mut start_features = Matrix::zeros(total_starts, d);
    let mut end_features = Matrix::zeros(total_ends, r);
    let mut start_idx = Vec::with_capacity(total_edges);
    let mut end_idx = Vec::with_capacity(total_edges);
    let mut start_off = 0u32;
    let mut end_off = 0u32;
    let mut spans = Vec::with_capacity(batch.len());
    let mut bad: Vec<bool> = Vec::with_capacity(batch.len());

    for req in &batch {
        // validate
        let valid = req.start_features.iter().all(|f| f.len() == d)
            && req.end_features.iter().all(|f| f.len() == r)
            && req.edges.iter().all(|&(s, e)| {
                (s as usize) < req.start_features.len() && (e as usize) < req.end_features.len()
            });
        bad.push(!valid);
        if !valid {
            spans.push(0);
            continue;
        }
        for (i, f) in req.start_features.iter().enumerate() {
            start_features.row_mut(start_off as usize + i).copy_from_slice(f);
        }
        for (j, f) in req.end_features.iter().enumerate() {
            end_features.row_mut(end_off as usize + j).copy_from_slice(f);
        }
        for &(s, e) in &req.edges {
            start_idx.push(start_off + s);
            end_idx.push(end_off + e);
        }
        spans.push(req.edges.len());
        start_off += req.start_features.len() as u32;
        end_off += req.end_features.len() as u32;
    }

    let n_scored = start_idx.len();
    let scores = if n_scored > 0 {
        let ds = Dataset {
            start_features,
            end_features,
            start_idx,
            end_idx,
            labels: vec![0.0; n_scored],
            name: "server-batch".into(),
        };
        model.predict_threaded(&ds, threads)
    } else {
        Vec::new()
    };

    // Update stats BEFORE delivering replies so a client that observed its
    // reply also observes the counters.
    stats.requests.fetch_add(batch.len(), Ordering::Relaxed);
    stats.batches.fetch_add(1, Ordering::Relaxed);
    stats.edges_scored.fetch_add(n_scored, Ordering::Relaxed);

    // Scatter replies.
    let mut cursor = 0usize;
    for (req, (&span, &is_bad)) in batch.iter().zip(spans.iter().zip(&bad)) {
        if is_bad {
            let _ = req.reply.send(vec![f64::NAN; req.edges.len()]);
            continue;
        }
        let _ = req.reply.send(scores[cursor..cursor + span].to_vec());
        cursor += span;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvt::KronIndex;
    use crate::kernels::KernelKind;
    use crate::util::rng::Pcg32;

    fn toy_model(seed: u64) -> DualModel {
        let mut rng = Pcg32::seeded(seed);
        let (m, q, n) = (6, 5, 15);
        DualModel {
            dual_coef: rng.normal_vec(n),
            train_start_features: Matrix::from_fn(m, 3, |_, _| rng.normal()),
            train_end_features: Matrix::from_fn(q, 2, |_, _| rng.normal()),
            train_idx: KronIndex::new(
                (0..n).map(|_| rng.below(q) as u32).collect(),
                (0..n).map(|_| rng.below(m) as u32).collect(),
            ),
            kernel_d: KernelKind::Gaussian { gamma: 0.3 },
            kernel_t: KernelKind::Gaussian { gamma: 0.3 },
        }
    }

    fn request_data(rng: &mut Pcg32, u: usize, v: usize, t: usize) -> (Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<(u32, u32)>) {
        let sf: Vec<Vec<f64>> = (0..u).map(|_| rng.normal_vec(3)).collect();
        let ef: Vec<Vec<f64>> = (0..v).map(|_| rng.normal_vec(2)).collect();
        let edges: Vec<(u32, u32)> =
            (0..t).map(|_| (rng.below(u) as u32, rng.below(v) as u32)).collect();
        (sf, ef, edges)
    }

    #[test]
    fn server_matches_direct_prediction() {
        let model = toy_model(1100);
        let mut rng = Pcg32::seeded(1101);
        let (sf, ef, edges) = request_data(&mut rng, 4, 3, 10);

        // direct prediction for reference
        let ds = Dataset {
            start_features: Matrix::from_fn(4, 3, |i, j| sf[i][j]),
            end_features: Matrix::from_fn(3, 2, |i, j| ef[i][j]),
            start_idx: edges.iter().map(|&(s, _)| s).collect(),
            end_idx: edges.iter().map(|&(_, e)| e).collect(),
            labels: vec![0.0; 10],
            name: "direct".into(),
        };
        let direct = model.predict(&ds);

        let server = PredictServer::start(model, ServerConfig::default());
        let served = server.predict_blocking(sf, ef, edges).unwrap();
        crate::linalg::vecops::assert_allclose(&served, &direct, 1e-10, 1e-10);
        assert_eq!(server.stats().requests.load(Ordering::Relaxed), 1);
        server.shutdown();
    }

    #[test]
    fn concurrent_requests_are_all_answered() {
        let model = toy_model(1102);
        let server =
            PredictServer::start(model, ServerConfig { max_batch_edges: 1000, threads: 2 });
        let sender = server.sender();
        let mut replies = Vec::new();
        let mut rng = Pcg32::seeded(1103);
        for _ in 0..20 {
            let (sf, ef, edges) = request_data(&mut rng, 3, 3, 6);
            let (tx, rx) = channel();
            sender
                .send(PredictRequest {
                    start_features: sf,
                    end_features: ef,
                    edges,
                    reply: tx,
                })
                .unwrap();
            replies.push(rx);
        }
        drop(sender); // release our clone so shutdown() can disconnect the worker
        for rx in replies {
            let scores = rx.recv().unwrap();
            assert_eq!(scores.len(), 6);
            assert!(scores.iter().all(|s| s.is_finite()));
        }
        let total = server.stats().edges_scored.load(Ordering::Relaxed);
        assert_eq!(total, 120);
        server.shutdown();
    }

    #[test]
    fn invalid_request_gets_nan_reply_without_poisoning_batch() {
        let model = toy_model(1104);
        let server = PredictServer::start(model, ServerConfig::default());
        // bad: edge references missing vertex
        let bad = server.predict_blocking(
            vec![vec![0.0; 3]],
            vec![vec![0.0; 2]],
            vec![(0, 5)],
        );
        let scores = bad.unwrap();
        assert!(scores[0].is_nan());
        // a good request still works afterwards
        let mut rng = Pcg32::seeded(1105);
        let (sf, ef, edges) = request_data(&mut rng, 2, 2, 3);
        let good = server.predict_blocking(sf, ef, edges).unwrap();
        assert!(good.iter().all(|s| s.is_finite()));
        server.shutdown();
    }
}
