//! Layer-3 coordinator: routing between the native GVT loops and the PJRT
//! dense path, a batched + cached + sharded zero-shot prediction server, and
//! the training-job orchestrator behind the CLI.

pub mod router;
pub mod server;
pub mod jobs;

pub use router::{Route, Router, RouterConfig};
pub use server::{PredictRequest, PredictServer, ServerConfig, ServerStats};
pub use jobs::{run_cv_jobs, run_cv_path_jobs, CvJobResult, CvPathJobResult, WorkerPool};
