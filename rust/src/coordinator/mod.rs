//! Layer-3 coordinator: routing between the native GVT loops and the PJRT
//! dense path, a batched + cached + sharded + fault-tolerant zero-shot
//! prediction server (typed errors, deadlines, supervised workers,
//! zero-downtime hot swap), a TCP/JSON-lines network front-end with a
//! vertex-affine shard router on top, the deterministic fault-injection
//! harness that proves those guarantees, and the training-job orchestrator
//! behind the CLI.
//!
//! The serving stack, bottom to top (dataflow in `docs/ARCHITECTURE.md`,
//! wire grammar in `docs/SERVING.md`):
//!
//! 1. [`server::PredictServer`] — merger + supervised scoring pool over one
//!    hot-swappable [`PredictContext`](crate::model::PredictContext);
//! 2. [`net::NetServer`] — newline-delimited JSON over TCP, one acceptor +
//!    per-connection reader/writer threads, every [`PredictError`] mapped
//!    to a wire error code;
//! 3. [`shard::ShardRouter`] — rendezvous-hash routing by start-vertex
//!    content across N backends, scatter/merge, failure ejection +
//!    re-probe.

pub mod faults;
pub mod jobs;
pub mod net;
pub mod router;
pub mod server;
pub mod shard;

pub use faults::FaultPlan;
pub use jobs::{
    run_cv_jobs, run_cv_path_jobs, CvJobResult, CvPathJobResult, RespawnPolicy, WorkerPool,
};
pub use net::{NetClient, NetServer, NetServerConfig, NetStats};
pub use router::{Route, Router, RouterConfig};
pub use server::{
    PredictError, PredictReply, PredictRequest, PredictServer, ServerConfig, ServerStats,
};
pub use shard::{LocalShard, NetShard, RouterStats, ShardBackend, ShardRouter, ShardRouterConfig};
