//! Layer-3 coordinator: routing between the native GVT loops and the PJRT
//! dense path, a batched + cached + sharded + fault-tolerant zero-shot
//! prediction server (typed errors, deadlines, supervised workers,
//! zero-downtime hot swap), the deterministic fault-injection harness that
//! proves those guarantees, and the training-job orchestrator behind the
//! CLI.

pub mod faults;
pub mod jobs;
pub mod router;
pub mod server;

pub use faults::FaultPlan;
pub use jobs::{
    run_cv_jobs, run_cv_path_jobs, CvJobResult, CvPathJobResult, RespawnPolicy, WorkerPool,
};
pub use router::{Route, Router, RouterConfig};
pub use server::{
    PredictError, PredictReply, PredictRequest, PredictServer, ServerConfig, ServerStats,
};
