//! Training-job orchestration: run cross-validation folds (or any
//! train→evaluate closure) across worker threads with deterministic result
//! ordering — plus a small long-lived [`WorkerPool`] used by the serving
//! coordinator's scoring shards.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use crate::data::Dataset;

/// Respawn policy for a supervised [`WorkerPool`]: how many panicked
/// workers the pool replaces, how quickly, and where the fault counters are
/// published (owners like `ServerStats` pass their own atomics, mirroring
/// the kernel-row-cache counter pattern).
#[derive(Debug, Clone)]
pub struct RespawnPolicy {
    /// Pool-wide budget of worker respawns. Once exhausted, a panicking
    /// worker stays dead — the guard against a deterministic panic (a
    /// poison-pill job) respawning forever.
    pub max_respawns: usize,
    /// Backoff before the first respawn of a slot, in milliseconds.
    pub backoff_base_ms: u64,
    /// Backoff cap: a slot's delay doubles on every consecutive panic up to
    /// this bound.
    pub backoff_cap_ms: u64,
    /// Handler panics observed by the supervisors.
    pub panics: Arc<AtomicUsize>,
    /// Workers respawned after a panic.
    pub respawns: Arc<AtomicUsize>,
}

impl Default for RespawnPolicy {
    fn default() -> Self {
        RespawnPolicy {
            max_respawns: 16,
            backoff_base_ms: 5,
            backoff_cap_ms: 200,
            panics: Arc::new(AtomicUsize::new(0)),
            respawns: Arc::new(AtomicUsize::new(0)),
        }
    }
}

/// A small, long-lived pool of worker threads draining jobs from one shared
/// bounded queue.
///
/// Unlike [`run_cv_jobs`] (scoped, one-shot, result-ordered), the pool lives
/// for the owner's lifetime and processes an open-ended job stream — the
/// prediction server uses it to shard merged batches across scoring workers.
/// The queue is a [`sync_channel`], so `queue_cap` bounds in-flight jobs and
/// [`WorkerPool::submit`] blocks when the pool is saturated (backpressure
/// that propagates to upstream submitters).
///
/// The pool is **supervised**: every worker runs on a child thread watched
/// by a per-slot supervisor, and a handler panic costs only the job that
/// panicked — the supervisor observes the crash through `join`, counts it,
/// and respawns the worker (capped budget, exponential backoff) so pool
/// capacity never silently shrinks. The panicked job itself is lost; its
/// owner observes that through whatever reply channel the job carried.
///
/// Dropping the pool is a graceful shutdown: the queue disconnects, workers
/// finish whatever is already queued, and the drop joins them.
pub struct WorkerPool<J: Send + 'static> {
    tx: Option<SyncSender<J>>,
    workers: Vec<JoinHandle<()>>,
}

impl<J: Send + 'static> WorkerPool<J> {
    /// Spawn `workers` supervised threads (min 1) running `handler` on each
    /// job, with the default [`RespawnPolicy`]. `queue_cap` bounds the
    /// number of submitted-but-unclaimed jobs.
    pub fn spawn<F>(workers: usize, queue_cap: usize, handler: F) -> WorkerPool<J>
    where
        F: Fn(J) + Send + Sync + 'static,
    {
        WorkerPool::spawn_supervised(workers, queue_cap, RespawnPolicy::default(), handler)
    }

    /// [`WorkerPool::spawn`] with an explicit supervision policy — the
    /// prediction server passes its `ServerStats` counters here.
    pub fn spawn_supervised<F>(
        workers: usize,
        queue_cap: usize,
        policy: RespawnPolicy,
        handler: F,
    ) -> WorkerPool<J>
    where
        F: Fn(J) + Send + Sync + 'static,
    {
        let (tx, rx) = sync_channel::<J>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let handler = Arc::new(handler);
        let budget = Arc::new(AtomicUsize::new(policy.max_respawns));
        let workers = (0..workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let handler = Arc::clone(&handler);
                let policy = policy.clone();
                let budget = Arc::clone(&budget);
                std::thread::spawn(move || supervise(rx, handler, policy, budget))
            })
            .collect();
        WorkerPool { tx: Some(tx), workers }
    }

    /// Submit one job, blocking while the queue is full. `Err` only after
    /// every worker has exited (respawn budget exhausted by panics).
    pub fn submit(&self, job: J) -> Result<(), String> {
        self.tx
            .as_ref()
            .expect("pool running")
            .send(job)
            .map_err(|_| "worker pool stopped".to_string())
    }

    /// Non-blocking [`WorkerPool::submit`]: [`TrySendError::Full`] returns
    /// the job back when the queue is full so the caller can shed load
    /// instead of waiting; [`TrySendError::Disconnected`] means every worker
    /// has exited (the respawn budget ran out) and retrying is pointless.
    pub fn try_submit(&self, job: J) -> Result<(), TrySendError<J>> {
        self.tx.as_ref().expect("pool running").try_send(job)
    }

    /// A cloneable submission handle, so another thread can feed the pool
    /// while the owner keeps it for shutdown. The pool's workers exit only
    /// after *every* handle (including the pool's own) is dropped and the
    /// queue has drained.
    pub fn sender(&self) -> SyncSender<J> {
        self.tx.as_ref().expect("pool running").clone()
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Graceful shutdown: stop accepting jobs, finish the queue, join the
    /// workers. (Dropping the pool does the same.)
    pub fn shutdown(mut self) {
        self.join();
    }

    fn join(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl<J: Send + 'static> Drop for WorkerPool<J> {
    fn drop(&mut self) {
        self.join();
    }
}

/// One supervisor slot: run the worker loop on a child thread and, while
/// the pool-wide respawn budget lasts, replace the child whenever it
/// panics. Panic isolation is the thread boundary itself — no
/// `catch_unwind`, no `UnwindSafe` bounds on the handler — and a clean
/// child exit (queue disconnected) ends the supervisor too.
fn supervise<J, F>(
    rx: Arc<Mutex<Receiver<J>>>,
    handler: Arc<F>,
    policy: RespawnPolicy,
    budget: Arc<AtomicUsize>,
) where
    J: Send + 'static,
    F: Fn(J) + Send + Sync + 'static,
{
    let mut consecutive: u32 = 0;
    loop {
        let child = {
            let rx = Arc::clone(&rx);
            let handler = Arc::clone(&handler);
            std::thread::spawn(move || worker_loop(rx, handler))
        };
        if child.join().is_ok() {
            return; // clean exit: every sender dropped and the queue drained
        }
        // The child panicked mid-job. That job is lost (its owner sees the
        // dropped reply channel); the pool's *capacity* must not be.
        policy.panics.fetch_add(1, Ordering::Relaxed);
        let within_budget = budget
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |b| b.checked_sub(1))
            .is_ok();
        if !within_budget {
            return; // budget exhausted — this slot stays dead
        }
        let delay = policy
            .backoff_base_ms
            .saturating_mul(1u64 << consecutive.min(16))
            .min(policy.backoff_cap_ms);
        consecutive += 1;
        if delay > 0 {
            std::thread::sleep(Duration::from_millis(delay));
        }
        policy.respawns.fetch_add(1, Ordering::Relaxed);
    }
}

/// The actual worker: drain jobs until every sender is gone. The queue lock
/// is held only while waiting for one job — never across `handler`, so a
/// handler panic cannot poison the queue for the survivors.
fn worker_loop<J, F>(rx: Arc<Mutex<Receiver<J>>>, handler: Arc<F>)
where
    J: Send + 'static,
    F: Fn(J),
{
    loop {
        let job = {
            let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
            guard.recv()
        };
        match job {
            Ok(job) => handler(job),
            Err(_) => return,
        }
    }
}

/// Result of one CV fold job.
#[derive(Debug, Clone)]
pub struct CvJobResult {
    /// Fold index (input order).
    pub fold: usize,
    /// Test AUC the job returned.
    pub auc: f64,
    /// Wall-clock seconds the job took.
    pub train_secs: f64,
    /// Training edges in the fold.
    pub train_edges: usize,
    /// Test edges in the fold.
    pub test_edges: usize,
}

/// Result of one multi-λ (regularization-path) CV fold job: one AUC per
/// hyper-parameter evaluated through the batched compute path.
#[derive(Debug, Clone)]
pub struct CvPathJobResult {
    /// Fold index (input order).
    pub fold: usize,
    /// Per-hyper-parameter test AUCs the job returned (one per λ).
    pub aucs: Vec<f64>,
    /// Wall-clock seconds the job took.
    pub train_secs: f64,
    /// Training edges in the fold.
    pub train_edges: usize,
    /// Test edges in the fold.
    pub test_edges: usize,
}

/// Shared fold fan-out: runs `job` over every fold with up to `threads`
/// scoped workers and returns `(fold, output, seconds)` in fold order.
fn run_fold_jobs<R, F>(
    folds: &[(Dataset, Dataset)],
    threads: usize,
    job: F,
) -> Vec<(usize, R, f64)>
where
    R: Send,
    F: Fn(&Dataset, &Dataset) -> R + Sync,
{
    let run_one = |fold: usize, train: &Dataset, test: &Dataset| -> (usize, R, f64) {
        let t = crate::util::timer::Timer::start();
        let out = job(train, test);
        (fold, out, t.elapsed_secs())
    };

    if threads <= 1 || folds.len() <= 1 {
        return folds
            .iter()
            .enumerate()
            .map(|(i, (tr, te))| run_one(i, tr, te))
            .collect();
    }

    let mut results: Vec<Option<(usize, R, f64)>> = (0..folds.len()).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let results_mx = std::sync::Mutex::new(&mut results);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(folds.len()) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= folds.len() {
                    break;
                }
                let (tr, te) = &folds[i];
                let res = run_one(i, tr, te);
                results_mx.lock().unwrap()[i] = Some(res);
            });
        }
    });
    results.into_iter().map(|r| r.expect("every fold executed")).collect()
}

/// Run `job(train, test) -> auc` over every fold, using up to `threads`
/// worker threads (scoped; results return in fold order). `threads = 0` or
/// `1` runs inline.
pub fn run_cv_jobs<F>(folds: &[(Dataset, Dataset)], threads: usize, job: F) -> Vec<CvJobResult>
where
    F: Fn(&Dataset, &Dataset) -> f64 + Sync,
{
    run_fold_jobs(folds, threads, job)
        .into_iter()
        .map(|(fold, auc, train_secs)| CvJobResult {
            fold,
            auc,
            train_secs,
            train_edges: folds[fold].0.n_edges(),
            test_edges: folds[fold].1.n_edges(),
        })
        .collect()
}

/// Run `job(train, test) -> per-λ AUCs` over every fold — the batched
/// (regularization-path) sibling of [`run_cv_jobs`]: each fold job trains a
/// whole λ grid through the multi-RHS compute core and scores every model in
/// one batched prediction, so the fold pays one kernel build and one solver
/// run for the entire grid.
pub fn run_cv_path_jobs<F>(
    folds: &[(Dataset, Dataset)],
    threads: usize,
    job: F,
) -> Vec<CvPathJobResult>
where
    F: Fn(&Dataset, &Dataset) -> Vec<f64> + Sync,
{
    run_fold_jobs(folds, threads, job)
        .into_iter()
        .map(|(fold, aucs, train_secs)| CvPathJobResult {
            fold,
            aucs,
            train_secs,
            train_edges: folds[fold].0.n_edges(),
            test_edges: folds[fold].1.n_edges(),
        })
        .collect()
}

/// Mean AUC across fold results.
pub fn mean_auc(results: &[CvJobResult]) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    results.iter().map(|r| r.auc).sum::<f64>() / results.len() as f64
}

/// Per-λ mean AUC across path fold results: entry `j` averages `aucs[j]`
/// over the folds that evaluated the expected `grid_len`-sized λ grid.
///
/// A fold whose job returned a different number of AUCs (a diverged or
/// mis-configured fold) is **skipped with a note on stderr** instead of
/// aborting the whole CV run; `Err` only when *no* fold matches the grid.
pub fn mean_auc_path(results: &[CvPathJobResult], grid_len: usize) -> Result<Vec<f64>, String> {
    let mut means = vec![0.0; grid_len];
    let mut used = 0usize;
    for r in results {
        if r.aucs.len() != grid_len {
            eprintln!(
                "mean_auc_path: skipping fold {} — it returned {} AUCs for a {grid_len}-λ grid",
                r.fold,
                r.aucs.len()
            );
            continue;
        }
        for (m, &a) in means.iter_mut().zip(&r.aucs) {
            *m += a;
        }
        used += 1;
    }
    if used == 0 {
        return Err(format!(
            "mean_auc_path: none of the {} fold results evaluated the expected {grid_len}-λ grid",
            results.len()
        ));
    }
    for m in &mut means {
        *m /= used as f64;
    }
    Ok(means)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::checkerboard::CheckerboardConfig;

    fn folds() -> Vec<(Dataset, Dataset)> {
        let ds = CheckerboardConfig { m: 30, q: 30, density: 0.5, noise: 0.1, seed: 7, ..Default::default() }.generate();
        ds.ninefold_cv(3)
    }

    #[test]
    fn inline_and_threaded_agree() {
        let folds = folds();
        let job = |tr: &Dataset, te: &Dataset| -> f64 {
            // cheap deterministic pseudo-job
            (tr.n_edges() % 97) as f64 + (te.n_edges() % 89) as f64 / 100.0
        };
        let seq = run_cv_jobs(&folds, 1, job);
        let par = run_cv_jobs(&folds, 4, job);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.fold, b.fold);
            assert_eq!(a.auc, b.auc);
        }
    }

    #[test]
    fn worker_pool_processes_all_jobs() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let done = Arc::new(AtomicUsize::new(0));
        let sum = Arc::new(AtomicUsize::new(0));
        let pool = {
            let (done, sum) = (done.clone(), sum.clone());
            WorkerPool::spawn(3, 4, move |j: usize| {
                sum.fetch_add(j, Ordering::Relaxed);
                done.fetch_add(1, Ordering::Relaxed);
            })
        };
        assert_eq!(pool.workers(), 3);
        for j in 0..50 {
            pool.submit(j).unwrap();
        }
        pool.shutdown(); // joins → every queued job ran
        assert_eq!(done.load(Ordering::Relaxed), 50);
        assert_eq!(sum.load(Ordering::Relaxed), (0..50).sum::<usize>());
    }

    #[test]
    fn worker_pool_try_submit_sheds_load_when_full() {
        let gate = Arc::new(Mutex::new(()));
        let guard = gate.lock().unwrap();
        let pool = {
            let gate = gate.clone();
            WorkerPool::spawn(1, 1, move |_: usize| {
                let _unblock = gate.lock().unwrap();
            })
        };
        // First job occupies the worker (blocked on the gate), second fills
        // the queue; eventually try_submit must report Full.
        pool.submit(0).unwrap();
        let mut rejected = false;
        for j in 1..10 {
            if pool.try_submit(j).is_err() {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "bounded queue must eventually reject");
        drop(guard);
        pool.shutdown();
    }

    #[test]
    fn path_jobs_inline_and_threaded_agree() {
        let folds = folds();
        let job = |tr: &Dataset, te: &Dataset| -> Vec<f64> {
            vec![(tr.n_edges() % 13) as f64, (te.n_edges() % 11) as f64]
        };
        let seq = run_cv_path_jobs(&folds, 1, job);
        let par = run_cv_path_jobs(&folds, 4, job);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.fold, b.fold);
            assert_eq!(a.aucs, b.aucs);
            assert!(a.train_edges > 0 && a.test_edges > 0);
        }
        let means = mean_auc_path(&seq, 2).expect("every fold evaluated the 2-λ grid");
        assert_eq!(means.len(), 2);
        assert!(mean_auc_path(&[], 2).is_err(), "no folds at all is an error");
    }

    /// One bad fold (wrong λ-grid length) must be skipped, not abort the
    /// aggregate — and a grid no fold matches is a clean `Err`, not a panic.
    #[test]
    fn mean_auc_path_skips_mismatched_folds() {
        let mk = |fold, aucs: Vec<f64>| CvPathJobResult {
            fold,
            aucs,
            train_secs: 0.0,
            train_edges: 1,
            test_edges: 1,
        };
        let results = vec![mk(0, vec![0.6, 0.8]), mk(1, vec![0.5]), mk(2, vec![0.8, 0.6])];
        let means = mean_auc_path(&results, 2).expect("two folds match the grid");
        assert!((means[0] - 0.7).abs() < 1e-12 && (means[1] - 0.7).abs() < 1e-12);
        assert!(mean_auc_path(&results, 3).is_err(), "no fold evaluated a 3-λ grid");
    }

    /// Regression for the silent capacity-loss bug: a handler panic used to
    /// kill the worker thread forever. A supervised pool must respawn the
    /// worker and still complete every non-poison job at full worker count.
    #[test]
    fn pool_survives_handler_panics_and_completes_jobs() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let done = Arc::new(AtomicUsize::new(0));
        let policy = RespawnPolicy { backoff_base_ms: 0, ..Default::default() };
        let (panics, respawns) = (policy.panics.clone(), policy.respawns.clone());
        let pool = {
            let done = done.clone();
            WorkerPool::spawn_supervised(2, 2, policy, move |j: usize| {
                assert!(j % 10 != 3, "poison job {j}");
                done.fetch_add(1, Ordering::Relaxed);
            })
        };
        assert_eq!(pool.workers(), 2);
        for j in 0..40 {
            pool.submit(j).expect("pool stays alive through panics");
        }
        pool.shutdown(); // joins → every queued job ran or panicked
        assert_eq!(done.load(Ordering::Relaxed), 36, "the 36 non-poison jobs all ran");
        assert_eq!(panics.load(Ordering::Relaxed), 4, "jobs 3/13/23/33 each panicked once");
        assert_eq!(respawns.load(Ordering::Relaxed), 4, "each panic was answered by a respawn");
    }

    /// When the respawn budget runs out, the pool winds down instead of
    /// looping: submissions start failing rather than hanging.
    #[test]
    fn exhausted_respawn_budget_stops_the_pool() {
        let policy = RespawnPolicy { max_respawns: 1, backoff_base_ms: 0, ..Default::default() };
        let respawns = policy.respawns.clone();
        let pool = WorkerPool::spawn_supervised(1, 1, policy, move |_: usize| {
            panic!("every job is poison");
        });
        // 1 initial worker + 1 respawn can consume at most 2 jobs; after
        // both died the queue disconnects and submit reports it.
        let mut stopped = false;
        for j in 0..100 {
            if pool.submit(j).is_err() {
                stopped = true;
                break;
            }
        }
        assert!(stopped, "an unsupervisable pool must refuse work, not hang");
        assert_eq!(respawns.load(std::sync::atomic::Ordering::Relaxed), 1);
        pool.shutdown();
    }

    #[test]
    fn mean_auc_aggregates() {
        let results = vec![
            CvJobResult { fold: 0, auc: 0.6, train_secs: 0.0, train_edges: 1, test_edges: 1 },
            CvJobResult { fold: 1, auc: 0.8, train_secs: 0.0, train_edges: 1, test_edges: 1 },
        ];
        assert!((mean_auc(&results) - 0.7).abs() < 1e-12);
        assert_eq!(mean_auc(&[]), 0.0);
    }
}
