//! Training-job orchestration: run cross-validation folds (or any
//! train→evaluate closure) across worker threads with deterministic result
//! ordering — plus a small long-lived [`WorkerPool`] used by the serving
//! coordinator's scoring shards.

use std::sync::mpsc::{sync_channel, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::data::Dataset;

/// A small, long-lived pool of worker threads draining jobs from one shared
/// bounded queue.
///
/// Unlike [`run_cv_jobs`] (scoped, one-shot, result-ordered), the pool lives
/// for the owner's lifetime and processes an open-ended job stream — the
/// prediction server uses it to shard merged batches across scoring workers.
/// The queue is a [`sync_channel`], so `queue_cap` bounds in-flight jobs and
/// [`WorkerPool::submit`] blocks when the pool is saturated (backpressure
/// that propagates to upstream submitters).
///
/// Dropping the pool is a graceful shutdown: the queue disconnects, workers
/// finish whatever is already queued, and the drop joins them.
pub struct WorkerPool<J: Send + 'static> {
    tx: Option<SyncSender<J>>,
    workers: Vec<JoinHandle<()>>,
}

impl<J: Send + 'static> WorkerPool<J> {
    /// Spawn `workers` threads (min 1) running `handler` on each job.
    /// `queue_cap` bounds the number of submitted-but-unclaimed jobs.
    pub fn spawn<F>(workers: usize, queue_cap: usize, handler: F) -> WorkerPool<J>
    where
        F: Fn(J) + Send + Sync + 'static,
    {
        let (tx, rx) = sync_channel::<J>(queue_cap.max(1));
        let rx = Arc::new(Mutex::new(rx));
        let handler = Arc::new(handler);
        let workers = (0..workers.max(1))
            .map(|_| {
                let rx = Arc::clone(&rx);
                let handler = Arc::clone(&handler);
                std::thread::spawn(move || loop {
                    // Hold the lock only while waiting for one job; recv
                    // returns Err once the pool (the only sender) is dropped.
                    let job = {
                        let guard = rx.lock().unwrap_or_else(|p| p.into_inner());
                        guard.recv()
                    };
                    match job {
                        Ok(job) => handler(job),
                        Err(_) => return,
                    }
                })
            })
            .collect();
        WorkerPool { tx: Some(tx), workers }
    }

    /// Submit one job, blocking while the queue is full. `Err` only after
    /// every worker has exited (panic in the handler).
    pub fn submit(&self, job: J) -> Result<(), String> {
        self.tx
            .as_ref()
            .expect("pool running")
            .send(job)
            .map_err(|_| "worker pool stopped".to_string())
    }

    /// Non-blocking [`WorkerPool::submit`]: [`TrySendError::Full`] returns
    /// the job back when the queue is full so the caller can shed load
    /// instead of waiting; [`TrySendError::Disconnected`] means every worker
    /// has exited (panic in the handler) and retrying is pointless.
    pub fn try_submit(&self, job: J) -> Result<(), TrySendError<J>> {
        self.tx.as_ref().expect("pool running").try_send(job)
    }

    /// A cloneable submission handle, so another thread can feed the pool
    /// while the owner keeps it for shutdown. The pool's workers exit only
    /// after *every* handle (including the pool's own) is dropped and the
    /// queue has drained.
    pub fn sender(&self) -> SyncSender<J> {
        self.tx.as_ref().expect("pool running").clone()
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.workers.len()
    }

    /// Graceful shutdown: stop accepting jobs, finish the queue, join the
    /// workers. (Dropping the pool does the same.)
    pub fn shutdown(mut self) {
        self.join();
    }

    fn join(&mut self) {
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl<J: Send + 'static> Drop for WorkerPool<J> {
    fn drop(&mut self) {
        self.join();
    }
}

/// Result of one CV fold job.
#[derive(Debug, Clone)]
pub struct CvJobResult {
    /// Fold index (input order).
    pub fold: usize,
    /// Test AUC the job returned.
    pub auc: f64,
    /// Wall-clock seconds the job took.
    pub train_secs: f64,
    /// Training edges in the fold.
    pub train_edges: usize,
    /// Test edges in the fold.
    pub test_edges: usize,
}

/// Result of one multi-λ (regularization-path) CV fold job: one AUC per
/// hyper-parameter evaluated through the batched compute path.
#[derive(Debug, Clone)]
pub struct CvPathJobResult {
    /// Fold index (input order).
    pub fold: usize,
    /// Per-hyper-parameter test AUCs the job returned (one per λ).
    pub aucs: Vec<f64>,
    /// Wall-clock seconds the job took.
    pub train_secs: f64,
    /// Training edges in the fold.
    pub train_edges: usize,
    /// Test edges in the fold.
    pub test_edges: usize,
}

/// Shared fold fan-out: runs `job` over every fold with up to `threads`
/// scoped workers and returns `(fold, output, seconds)` in fold order.
fn run_fold_jobs<R, F>(
    folds: &[(Dataset, Dataset)],
    threads: usize,
    job: F,
) -> Vec<(usize, R, f64)>
where
    R: Send,
    F: Fn(&Dataset, &Dataset) -> R + Sync,
{
    let run_one = |fold: usize, train: &Dataset, test: &Dataset| -> (usize, R, f64) {
        let t = crate::util::timer::Timer::start();
        let out = job(train, test);
        (fold, out, t.elapsed_secs())
    };

    if threads <= 1 || folds.len() <= 1 {
        return folds
            .iter()
            .enumerate()
            .map(|(i, (tr, te))| run_one(i, tr, te))
            .collect();
    }

    let mut results: Vec<Option<(usize, R, f64)>> = (0..folds.len()).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let results_mx = std::sync::Mutex::new(&mut results);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(folds.len()) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= folds.len() {
                    break;
                }
                let (tr, te) = &folds[i];
                let res = run_one(i, tr, te);
                results_mx.lock().unwrap()[i] = Some(res);
            });
        }
    });
    results.into_iter().map(|r| r.expect("every fold executed")).collect()
}

/// Run `job(train, test) -> auc` over every fold, using up to `threads`
/// worker threads (scoped; results return in fold order). `threads = 0` or
/// `1` runs inline.
pub fn run_cv_jobs<F>(folds: &[(Dataset, Dataset)], threads: usize, job: F) -> Vec<CvJobResult>
where
    F: Fn(&Dataset, &Dataset) -> f64 + Sync,
{
    run_fold_jobs(folds, threads, job)
        .into_iter()
        .map(|(fold, auc, train_secs)| CvJobResult {
            fold,
            auc,
            train_secs,
            train_edges: folds[fold].0.n_edges(),
            test_edges: folds[fold].1.n_edges(),
        })
        .collect()
}

/// Run `job(train, test) -> per-λ AUCs` over every fold — the batched
/// (regularization-path) sibling of [`run_cv_jobs`]: each fold job trains a
/// whole λ grid through the multi-RHS compute core and scores every model in
/// one batched prediction, so the fold pays one kernel build and one solver
/// run for the entire grid.
pub fn run_cv_path_jobs<F>(
    folds: &[(Dataset, Dataset)],
    threads: usize,
    job: F,
) -> Vec<CvPathJobResult>
where
    F: Fn(&Dataset, &Dataset) -> Vec<f64> + Sync,
{
    run_fold_jobs(folds, threads, job)
        .into_iter()
        .map(|(fold, aucs, train_secs)| CvPathJobResult {
            fold,
            aucs,
            train_secs,
            train_edges: folds[fold].0.n_edges(),
            test_edges: folds[fold].1.n_edges(),
        })
        .collect()
}

/// Mean AUC across fold results.
pub fn mean_auc(results: &[CvJobResult]) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    results.iter().map(|r| r.auc).sum::<f64>() / results.len() as f64
}

/// Per-λ mean AUC across path fold results (entry `j` averages `aucs[j]`
/// over the folds). Panics if folds disagree on the grid length.
pub fn mean_auc_path(results: &[CvPathJobResult]) -> Vec<f64> {
    let Some(first) = results.first() else {
        return Vec::new();
    };
    let k = first.aucs.len();
    let mut means = vec![0.0; k];
    for r in results {
        assert_eq!(r.aucs.len(), k, "folds evaluated different λ grids");
        for (m, &a) in means.iter_mut().zip(&r.aucs) {
            *m += a;
        }
    }
    for m in &mut means {
        *m /= results.len() as f64;
    }
    means
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::checkerboard::CheckerboardConfig;

    fn folds() -> Vec<(Dataset, Dataset)> {
        let ds = CheckerboardConfig { m: 30, q: 30, density: 0.5, noise: 0.1, seed: 7, ..Default::default() }.generate();
        ds.ninefold_cv(3)
    }

    #[test]
    fn inline_and_threaded_agree() {
        let folds = folds();
        let job = |tr: &Dataset, te: &Dataset| -> f64 {
            // cheap deterministic pseudo-job
            (tr.n_edges() % 97) as f64 + (te.n_edges() % 89) as f64 / 100.0
        };
        let seq = run_cv_jobs(&folds, 1, job);
        let par = run_cv_jobs(&folds, 4, job);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.fold, b.fold);
            assert_eq!(a.auc, b.auc);
        }
    }

    #[test]
    fn worker_pool_processes_all_jobs() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let done = Arc::new(AtomicUsize::new(0));
        let sum = Arc::new(AtomicUsize::new(0));
        let pool = {
            let (done, sum) = (done.clone(), sum.clone());
            WorkerPool::spawn(3, 4, move |j: usize| {
                sum.fetch_add(j, Ordering::Relaxed);
                done.fetch_add(1, Ordering::Relaxed);
            })
        };
        assert_eq!(pool.workers(), 3);
        for j in 0..50 {
            pool.submit(j).unwrap();
        }
        pool.shutdown(); // joins → every queued job ran
        assert_eq!(done.load(Ordering::Relaxed), 50);
        assert_eq!(sum.load(Ordering::Relaxed), (0..50).sum::<usize>());
    }

    #[test]
    fn worker_pool_try_submit_sheds_load_when_full() {
        let gate = Arc::new(Mutex::new(()));
        let guard = gate.lock().unwrap();
        let pool = {
            let gate = gate.clone();
            WorkerPool::spawn(1, 1, move |_: usize| {
                let _unblock = gate.lock().unwrap();
            })
        };
        // First job occupies the worker (blocked on the gate), second fills
        // the queue; eventually try_submit must report Full.
        pool.submit(0).unwrap();
        let mut rejected = false;
        for j in 1..10 {
            if pool.try_submit(j).is_err() {
                rejected = true;
                break;
            }
        }
        assert!(rejected, "bounded queue must eventually reject");
        drop(guard);
        pool.shutdown();
    }

    #[test]
    fn path_jobs_inline_and_threaded_agree() {
        let folds = folds();
        let job = |tr: &Dataset, te: &Dataset| -> Vec<f64> {
            vec![(tr.n_edges() % 13) as f64, (te.n_edges() % 11) as f64]
        };
        let seq = run_cv_path_jobs(&folds, 1, job);
        let par = run_cv_path_jobs(&folds, 4, job);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.fold, b.fold);
            assert_eq!(a.aucs, b.aucs);
            assert!(a.train_edges > 0 && a.test_edges > 0);
        }
        let means = mean_auc_path(&seq);
        assert_eq!(means.len(), 2);
        assert!(mean_auc_path(&[]).is_empty());
    }

    #[test]
    fn mean_auc_aggregates() {
        let results = vec![
            CvJobResult { fold: 0, auc: 0.6, train_secs: 0.0, train_edges: 1, test_edges: 1 },
            CvJobResult { fold: 1, auc: 0.8, train_secs: 0.0, train_edges: 1, test_edges: 1 },
        ];
        assert!((mean_auc(&results) - 0.7).abs() < 1e-12);
        assert_eq!(mean_auc(&[]), 0.0);
    }
}
