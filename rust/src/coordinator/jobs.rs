//! Training-job orchestration: run cross-validation folds (or any
//! train→evaluate closure) across worker threads with deterministic result
//! ordering.

use crate::data::Dataset;

/// Result of one CV fold job.
#[derive(Debug, Clone)]
pub struct CvJobResult {
    /// Fold index (input order).
    pub fold: usize,
    /// Test AUC the job returned.
    pub auc: f64,
    /// Wall-clock seconds the job took.
    pub train_secs: f64,
    /// Training edges in the fold.
    pub train_edges: usize,
    /// Test edges in the fold.
    pub test_edges: usize,
}

/// Run `job(train, test) -> auc` over every fold, using up to `threads`
/// worker threads (scoped; results return in fold order). `threads = 0` or
/// `1` runs inline.
pub fn run_cv_jobs<F>(folds: &[(Dataset, Dataset)], threads: usize, job: F) -> Vec<CvJobResult>
where
    F: Fn(&Dataset, &Dataset) -> f64 + Sync,
{
    let run_one = |fold: usize, train: &Dataset, test: &Dataset| -> CvJobResult {
        let t = crate::util::timer::Timer::start();
        let auc = job(train, test);
        CvJobResult {
            fold,
            auc,
            train_secs: t.elapsed_secs(),
            train_edges: train.n_edges(),
            test_edges: test.n_edges(),
        }
    };

    if threads <= 1 || folds.len() <= 1 {
        return folds
            .iter()
            .enumerate()
            .map(|(i, (tr, te))| run_one(i, tr, te))
            .collect();
    }

    let mut results: Vec<Option<CvJobResult>> = (0..folds.len()).map(|_| None).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    let results_mx = std::sync::Mutex::new(&mut results);
    std::thread::scope(|scope| {
        for _ in 0..threads.min(folds.len()) {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= folds.len() {
                    break;
                }
                let (tr, te) = &folds[i];
                let res = run_one(i, tr, te);
                results_mx.lock().unwrap()[i] = Some(res);
            });
        }
    });
    results.into_iter().map(|r| r.expect("every fold executed")).collect()
}

/// Mean AUC across fold results.
pub fn mean_auc(results: &[CvJobResult]) -> f64 {
    if results.is_empty() {
        return 0.0;
    }
    results.iter().map(|r| r.auc).sum::<f64>() / results.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::checkerboard::CheckerboardConfig;

    fn folds() -> Vec<(Dataset, Dataset)> {
        let ds = CheckerboardConfig { m: 30, q: 30, density: 0.5, noise: 0.1, seed: 7, ..Default::default() }.generate();
        ds.ninefold_cv(3)
    }

    #[test]
    fn inline_and_threaded_agree() {
        let folds = folds();
        let job = |tr: &Dataset, te: &Dataset| -> f64 {
            // cheap deterministic pseudo-job
            (tr.n_edges() % 97) as f64 + (te.n_edges() % 89) as f64 / 100.0
        };
        let seq = run_cv_jobs(&folds, 1, job);
        let par = run_cv_jobs(&folds, 4, job);
        assert_eq!(seq.len(), par.len());
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.fold, b.fold);
            assert_eq!(a.auc, b.auc);
        }
    }

    #[test]
    fn mean_auc_aggregates() {
        let results = vec![
            CvJobResult { fold: 0, auc: 0.6, train_secs: 0.0, train_edges: 1, test_edges: 1 },
            CvJobResult { fold: 1, auc: 0.8, train_secs: 0.0, train_edges: 1, test_edges: 1 },
        ];
        assert!((mean_auc(&results) - 0.7).abs() < 1e-12);
        assert_eq!(mean_auc(&[]), 0.0);
    }
}
