//! Shard routing: fan one logical prediction service out over N serving
//! backends (remote [`NetClient`] connections or in-process servers),
//! with vertex-affine routing, scatter/merge for batches that span
//! shards, and per-shard health tracking.
//!
//! **Why vertex-affine routing.** The serving hot path is dominated by
//! kernel rows k(x_new, X_train), and [`PredictContext`] keeps a
//! content-keyed LRU of them. Vertex identity *is* feature content, so the
//! router hashes each start-vertex feature row (FNV-1a over the exact
//! `f64` bit patterns) and picks its shard by rendezvous hashing — the
//! same vertex always lands on the same shard while that shard is
//! healthy, keeping each shard's cache hot for its slice of the vertex
//! universe, and shard loss only remaps the dead shard's slice.
//!
//! **Scatter/merge.** A batch whose edges hash to several shards is split
//! into per-shard sub-requests (feature rows deduplicated, edge indices
//! remapped), dispatched concurrently, and merged back into request
//! order. Per-edge scores depend only on the model and that edge's
//! feature rows — never on batch composition — so the merged result is
//! **bitwise identical** to scoring the whole batch on one unsharded
//! server with the same model.
//!
//! **Health.** A transport failure (connect refused, reset, response
//! timeout) or a `shutting_down` reply counts against a shard;
//! `eject_after` consecutive failures eject it for `probe_cooldown_ms`,
//! after which the next batch re-probes it (half-open). Typed
//! non-shutdown errors — invalid request, deadline, overload — mean the
//! shard is alive and are *not* health failures. Failed sub-batches are
//! re-routed to the surviving shards within the same call.
//!
//! [`PredictContext`]: crate::model::PredictContext

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use super::net::NetClient;
use super::server::{PredictError, PredictReply, PredictServer};

/// One serving backend the router can score a sub-batch on.
pub trait ShardBackend: Send + Sync {
    /// Human-readable backend name (address or label) for logs and errors.
    fn name(&self) -> String;

    /// Score a batch. `Ok` carries the server's typed reply (scores or
    /// [`PredictError`]); `Err(String)` is a transport failure — the
    /// backend could not be reached or did not answer.
    fn predict(
        &self,
        rows: &[Vec<f64>],
        cols: &[Vec<f64>],
        edges: &[(u32, u32)],
        deadline_ms: Option<u64>,
    ) -> Result<PredictReply, String>;
}

/// An in-process shard: a [`PredictServer`] behind the backend trait.
/// Used by tests and single-process multi-shard setups.
pub struct LocalShard {
    server: Arc<PredictServer>,
    label: String,
}

impl LocalShard {
    /// Wrap a running server as a shard backend.
    pub fn new(server: Arc<PredictServer>, label: &str) -> LocalShard {
        LocalShard { server, label: label.to_string() }
    }
}

impl ShardBackend for LocalShard {
    fn name(&self) -> String {
        self.label.clone()
    }

    fn predict(
        &self,
        rows: &[Vec<f64>],
        cols: &[Vec<f64>],
        edges: &[(u32, u32)],
        deadline_ms: Option<u64>,
    ) -> Result<PredictReply, String> {
        let (tx, rx) = std::sync::mpsc::channel();
        let mut req = super::server::PredictRequest::new(
            rows.to_vec(),
            cols.to_vec(),
            edges.to_vec(),
            tx,
        );
        if let Some(ms) = deadline_ms {
            req = req.with_deadline_ms(ms);
        } else if self.server.request_timeout_ms() > 0 {
            req = req.with_deadline_ms(self.server.request_timeout_ms());
        }
        let deadline = req.deadline;
        let _ = self.server.try_submit(req); // refusals answered on the reply channel
        super::server::wait_reply(&rx, deadline)
            .map(Ok)
            .unwrap_or_else(|e| Ok(PredictReply { result: Err(e), generation: 0 }))
    }
}

/// A remote shard: one lazily-(re)connected [`NetClient`] per backend.
/// A transport failure drops the cached connection, so the next attempt
/// (including a health re-probe) dials fresh.
pub struct NetShard {
    addr: String,
    conn: Mutex<Option<NetClient>>,
}

impl NetShard {
    /// A shard at a `host:port` address. No connection is made until the
    /// first request.
    pub fn new(addr: &str) -> NetShard {
        NetShard { addr: addr.to_string(), conn: Mutex::new(None) }
    }
}

impl ShardBackend for NetShard {
    fn name(&self) -> String {
        self.addr.clone()
    }

    fn predict(
        &self,
        rows: &[Vec<f64>],
        cols: &[Vec<f64>],
        edges: &[(u32, u32)],
        deadline_ms: Option<u64>,
    ) -> Result<PredictReply, String> {
        let mut guard = self.conn.lock().unwrap_or_else(|p| p.into_inner());
        if guard.is_none() {
            *guard = Some(NetClient::connect(&self.addr)?);
        }
        let client = guard.as_mut().expect("connection populated above");
        let out = client.predict(rows, cols, edges, deadline_ms);
        if out.is_err() {
            *guard = None; // reconnect on the next attempt
        }
        out
    }
}

/// Router health / ejection policy.
#[derive(Debug, Clone, Copy)]
pub struct ShardRouterConfig {
    /// Consecutive failures after which a shard is ejected.
    pub eject_after: usize,
    /// How long an ejected shard sits out before the next batch re-probes
    /// it (half-open).
    pub probe_cooldown_ms: u64,
}

impl Default for ShardRouterConfig {
    fn default() -> Self {
        ShardRouterConfig { eject_after: 3, probe_cooldown_ms: 1_000 }
    }
}

/// Router observability counters.
#[derive(Debug, Default)]
pub struct RouterStats {
    /// Batches routed (one per [`ShardRouter::predict`] call).
    pub routed: AtomicUsize,
    /// Batches whose edges spanned more than one shard (scatter/merge).
    pub scattered: AtomicUsize,
    /// Sub-batch failures charged against a shard's health.
    pub shard_failures: AtomicUsize,
    /// Shards ejected (consecutive-failure threshold crossed).
    pub ejections: AtomicUsize,
    /// Re-probes of ejected shards after their cooldown.
    pub reprobes: AtomicUsize,
}

struct Health {
    consecutive_failures: usize,
    ejected_until: Option<Instant>,
}

/// Vertex-affine scatter/merge router over N shard backends.
pub struct ShardRouter {
    shards: Vec<Box<dyn ShardBackend>>,
    health: Vec<Mutex<Health>>,
    cfg: ShardRouterConfig,
    stats: RouterStats,
}

impl ShardRouter {
    /// Build a router over the given backends (at least one).
    pub fn new(
        shards: Vec<Box<dyn ShardBackend>>,
        cfg: ShardRouterConfig,
    ) -> Result<ShardRouter, String> {
        if shards.is_empty() {
            return Err("a shard router needs at least one backend".into());
        }
        let health = shards
            .iter()
            .map(|_| Mutex::new(Health { consecutive_failures: 0, ejected_until: None }))
            .collect();
        Ok(ShardRouter { shards, health, cfg, stats: RouterStats::default() })
    }

    /// Number of configured shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Shards currently considered routable (not ejected, or past their
    /// re-probe cooldown).
    pub fn healthy_count(&self) -> usize {
        (0..self.shards.len()).filter(|&i| self.routable(i)).count()
    }

    /// Router counters.
    pub fn stats(&self) -> &RouterStats {
        &self.stats
    }

    /// Score a batch across the shards: hash-route each edge by its
    /// start-vertex feature row, dispatch per-shard sub-requests
    /// concurrently, merge scores back into request order. Sub-batches
    /// that fail on a shard (transport error or `shutting_down`) are
    /// re-routed to surviving shards within this call; `Err(String)` is
    /// returned only when every routable shard has been exhausted.
    pub fn predict(
        &self,
        rows: &[Vec<f64>],
        cols: &[Vec<f64>],
        edges: &[(u32, u32)],
        deadline_ms: Option<u64>,
    ) -> Result<PredictReply, String> {
        self.stats.routed.fetch_add(1, Ordering::Relaxed);
        // Pre-validate edge indices: the router must index `rows` to hash
        // vertices, so out-of-range edges are answered with the same typed
        // error the server itself would produce.
        for &(s, e) in edges {
            if s as usize >= rows.len() || e as usize >= cols.len() {
                let msg = format!(
                    "edge ({s}, {e}) references a vertex outside the request \
                     ({} start rows, {} end rows)",
                    rows.len(),
                    cols.len()
                );
                return Ok(PredictReply {
                    result: Err(PredictError::InvalidRequest(msg)),
                    generation: 0,
                });
            }
        }
        // Hash each distinct start vertex once.
        let keys: Vec<u64> = rows.iter().map(|row| vertex_key(row)).collect();

        let mut merged = vec![0.0_f64; edges.len()];
        let mut generation = 0_u64;
        // Edges still awaiting scores, as original positions.
        let mut unresolved: Vec<usize> = (0..edges.len()).collect();
        let mut excluded: Vec<bool> = vec![false; self.shards.len()];
        let mut shards_spanned = 0_usize;
        let mut last_failure = String::new();
        while !unresolved.is_empty() {
            let routable: Vec<usize> = (0..self.shards.len())
                .filter(|&i| !excluded[i] && self.routable(i))
                .collect();
            if routable.is_empty() {
                return Err(format!(
                    "no routable shard left for {} edge(s) (last failure: {})",
                    unresolved.len(),
                    if last_failure.is_empty() { "none" } else { &last_failure }
                ));
            }
            for &i in &routable {
                self.note_probe(i);
            }
            let subs = partition(rows, cols, edges, &keys, &unresolved, &routable);
            shards_spanned = shards_spanned.max(subs.len());
            let results: Vec<(usize, Result<PredictReply, String>, Vec<usize>)> =
                std::thread::scope(|scope| {
                    let handles: Vec<_> = subs
                        .into_iter()
                        .map(|sub| {
                            let shard = &self.shards[sub.shard];
                            scope.spawn(move || {
                                let out = shard.predict(
                                    &sub.rows,
                                    &sub.cols,
                                    &sub.edges,
                                    deadline_ms,
                                );
                                (sub.shard, out, sub.positions)
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().expect("shard dispatch")).collect()
                });
            unresolved.clear();
            for (shard, out, positions) in results {
                match out {
                    Ok(PredictReply { result: Ok(scores), generation: g }) => {
                        self.note_success(shard);
                        if scores.len() != positions.len() {
                            return Err(format!(
                                "shard {} answered {} scores for {} edges",
                                self.shards[shard].name(),
                                scores.len(),
                                positions.len()
                            ));
                        }
                        generation = generation.max(g);
                        for (&pos, &score) in positions.iter().zip(&scores) {
                            merged[pos] = score;
                        }
                    }
                    Ok(PredictReply { result: Err(PredictError::ShuttingDown), .. }) => {
                        // The backend is going away — treat like transport
                        // loss: charge health, re-route the sub-batch.
                        self.note_failure(shard);
                        last_failure =
                            format!("{}: shutting down", self.shards[shard].name());
                        excluded[shard] = true;
                        unresolved.extend(positions);
                    }
                    Ok(PredictReply { result: Err(e), generation: g }) => {
                        // Typed refusal from a live shard: the whole batch
                        // fails with that error, as it would unsharded.
                        self.note_success(shard);
                        return Ok(PredictReply {
                            result: Err(e),
                            generation: generation.max(g),
                        });
                    }
                    Err(transport) => {
                        self.note_failure(shard);
                        last_failure =
                            format!("{}: {transport}", self.shards[shard].name());
                        excluded[shard] = true;
                        unresolved.extend(positions);
                    }
                }
            }
            unresolved.sort_unstable();
        }
        if shards_spanned > 1 {
            self.stats.scattered.fetch_add(1, Ordering::Relaxed);
        }
        Ok(PredictReply { result: Ok(merged), generation })
    }

    /// Whether shard `i` may receive traffic right now.
    fn routable(&self, i: usize) -> bool {
        let h = self.health[i].lock().unwrap_or_else(|p| p.into_inner());
        match h.ejected_until {
            None => true,
            Some(t) => Instant::now() >= t,
        }
    }

    /// Count a re-probe when routing to a shard that sat out its cooldown.
    fn note_probe(&self, i: usize) {
        let h = self.health[i].lock().unwrap_or_else(|p| p.into_inner());
        if h.ejected_until.is_some() {
            self.stats.reprobes.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn note_success(&self, i: usize) {
        let mut h = self.health[i].lock().unwrap_or_else(|p| p.into_inner());
        h.consecutive_failures = 0;
        h.ejected_until = None;
    }

    fn note_failure(&self, i: usize) {
        self.stats.shard_failures.fetch_add(1, Ordering::Relaxed);
        let mut h = self.health[i].lock().unwrap_or_else(|p| p.into_inner());
        h.consecutive_failures += 1;
        if h.consecutive_failures >= self.cfg.eject_after && h.ejected_until.is_none() {
            h.ejected_until =
                Some(Instant::now() + Duration::from_millis(self.cfg.probe_cooldown_ms));
            self.stats.ejections.fetch_add(1, Ordering::Relaxed);
        } else if h.ejected_until.is_some() {
            // A failed re-probe restarts the cooldown.
            h.ejected_until =
                Some(Instant::now() + Duration::from_millis(self.cfg.probe_cooldown_ms));
        }
    }
}

/// One shard's slice of a batch: deduplicated feature rows, remapped
/// edges, and the original edge positions for the merge.
struct SubRequest {
    shard: usize,
    rows: Vec<Vec<f64>>,
    cols: Vec<Vec<f64>>,
    edges: Vec<(u32, u32)>,
    positions: Vec<usize>,
}

/// Partition `unresolved` edge positions across `routable` shards by
/// start-vertex hash. Sub-request edge order follows the original request
/// order (positions are visited ascending), so per-shard results merge
/// deterministically.
fn partition(
    rows: &[Vec<f64>],
    cols: &[Vec<f64>],
    edges: &[(u32, u32)],
    keys: &[u64],
    unresolved: &[usize],
    routable: &[usize],
) -> Vec<SubRequest> {
    let mut by_shard: HashMap<usize, SubRequest> = HashMap::new();
    let mut row_maps: HashMap<usize, HashMap<u32, u32>> = HashMap::new();
    let mut col_maps: HashMap<usize, HashMap<u32, u32>> = HashMap::new();
    for &pos in unresolved {
        let (s, e) = edges[pos];
        let shard = rendezvous(keys[s as usize], routable);
        let sub = by_shard.entry(shard).or_insert_with(|| SubRequest {
            shard,
            rows: Vec::new(),
            cols: Vec::new(),
            edges: Vec::new(),
            positions: Vec::new(),
        });
        let row_map = row_maps.entry(shard).or_default();
        let col_map = col_maps.entry(shard).or_default();
        let ls = *row_map.entry(s).or_insert_with(|| {
            sub.rows.push(rows[s as usize].clone());
            (sub.rows.len() - 1) as u32
        });
        let le = *col_map.entry(e).or_insert_with(|| {
            sub.cols.push(cols[e as usize].clone());
            (sub.cols.len() - 1) as u32
        });
        sub.edges.push((ls, le));
        sub.positions.push(pos);
    }
    let mut subs: Vec<SubRequest> = by_shard.into_values().collect();
    subs.sort_by_key(|s| s.shard);
    subs
}

/// FNV-1a over the exact bit patterns of a feature row — the same notion
/// of vertex identity the kernel-row cache uses (content, not position).
pub fn vertex_key(row: &[f64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325_u64;
    for &x in row {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    h
}

/// Rendezvous (highest-random-weight) hashing: each candidate shard gets
/// a mixed weight for this key; the highest wins. Adding or losing a
/// shard only remaps the vertices whose winner changed — no global
/// reshuffle.
pub fn rendezvous(key: u64, shard_ids: &[usize]) -> usize {
    *shard_ids
        .iter()
        .max_by_key(|&&s| mix(key, s as u64))
        .expect("rendezvous over a non-empty shard set")
}

/// SplitMix64-style finalizer over (key, shard).
fn mix(key: u64, shard: u64) -> u64 {
    let mut z = key ^ shard.wrapping_mul(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    /// Deterministic fake backend: score(edge) = f(start_row[0], end_row[0]),
    /// so merged results are checkable without a model. Fails the first
    /// `fail_first` calls with a transport error.
    struct MockShard {
        label: String,
        calls: AtomicUsize,
        fail_first: usize,
        generation: u64,
    }

    impl MockShard {
        fn new(label: &str, fail_first: usize) -> MockShard {
            MockShard {
                label: label.into(),
                calls: AtomicUsize::new(0),
                fail_first,
                generation: 0,
            }
        }
    }

    impl ShardBackend for MockShard {
        fn name(&self) -> String {
            self.label.clone()
        }

        fn predict(
            &self,
            rows: &[Vec<f64>],
            cols: &[Vec<f64>],
            edges: &[(u32, u32)],
            _deadline_ms: Option<u64>,
        ) -> Result<PredictReply, String> {
            let call = self.calls.fetch_add(1, Ordering::SeqCst);
            if call < self.fail_first {
                return Err("injected transport failure".into());
            }
            let scores = edges
                .iter()
                .map(|&(s, e)| rows[s as usize][0] * 1000.0 + cols[e as usize][0])
                .collect();
            Ok(PredictReply { result: Ok(scores), generation: self.generation })
        }
    }

    /// 32 distinct start vertices: enough that every shard in a 2- or
    /// 3-way split certainly receives traffic (the routing is a fixed
    /// deterministic hash, so this either always holds or never does —
    /// and with 32 keys, no shard going empty is the only realistic
    /// outcome).
    fn sample_batch() -> (Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<(u32, u32)>) {
        let rows: Vec<Vec<f64>> = (0..32).map(|i| vec![i as f64, 0.5]).collect();
        let cols: Vec<Vec<f64>> = (0..3).map(|j| vec![j as f64]).collect();
        let edges: Vec<(u32, u32)> =
            (0..32).flat_map(|s| (0..3).map(move |e| (s as u32, e as u32))).collect();
        (rows, cols, edges)
    }

    fn expected(rows: &[Vec<f64>], cols: &[Vec<f64>], edges: &[(u32, u32)]) -> Vec<f64> {
        edges.iter().map(|&(s, e)| rows[s as usize][0] * 1000.0 + cols[e as usize][0]).collect()
    }

    #[test]
    fn scatter_merge_preserves_request_order() {
        let shards: Vec<Box<dyn ShardBackend>> = (0..3)
            .map(|i| Box::new(MockShard::new(&format!("s{i}"), 0)) as Box<dyn ShardBackend>)
            .collect();
        let router = ShardRouter::new(shards, ShardRouterConfig::default()).unwrap();
        let (rows, cols, edges) = sample_batch();
        let reply = router.predict(&rows, &cols, &edges, None).unwrap();
        assert_eq!(reply.result.unwrap(), expected(&rows, &cols, &edges));
        assert_eq!(router.stats().scattered.load(Ordering::SeqCst), 1, "32 vertices span shards");
    }

    #[test]
    fn same_vertex_routes_to_same_shard() {
        let ids = vec![0, 1, 2];
        let key = vertex_key(&[3.25, -1.5]);
        let first = rendezvous(key, &ids);
        for _ in 0..10 {
            assert_eq!(rendezvous(key, &ids), first);
        }
        // Removing a non-winning shard must not move this vertex.
        let without: Vec<usize> = ids.iter().copied().filter(|&s| s != (first + 1) % 3).collect();
        assert_eq!(rendezvous(key, &without), first);
    }

    #[test]
    fn dead_shard_is_ejected_and_traffic_continues() {
        let shards: Vec<Box<dyn ShardBackend>> = vec![
            Box::new(MockShard::new("ok", 0)),
            Box::new(MockShard::new("dead", usize::MAX)),
        ];
        let cfg = ShardRouterConfig { eject_after: 2, probe_cooldown_ms: 60_000 };
        let router = ShardRouter::new(shards, cfg).unwrap();
        let (rows, cols, edges) = sample_batch();
        let want = expected(&rows, &cols, &edges);
        for _ in 0..4 {
            let reply = router.predict(&rows, &cols, &edges, None).unwrap();
            assert_eq!(reply.result.unwrap(), want, "every batch still scores fully");
        }
        assert_eq!(router.stats().ejections.load(Ordering::SeqCst), 1);
        assert_eq!(router.healthy_count(), 1, "dead shard sits out its cooldown");
        assert!(router.stats().shard_failures.load(Ordering::SeqCst) >= 2);
    }

    #[test]
    fn ejected_shard_is_reprobed_after_cooldown() {
        // Fails twice (ejection at eject_after=2), then recovers.
        let shards: Vec<Box<dyn ShardBackend>> = vec![
            Box::new(MockShard::new("flaky", 2)),
            Box::new(MockShard::new("ok", 0)),
        ];
        let cfg = ShardRouterConfig { eject_after: 2, probe_cooldown_ms: 1 };
        let router = ShardRouter::new(shards, cfg).unwrap();
        let (rows, cols, edges) = sample_batch();
        let want = expected(&rows, &cols, &edges);
        for _ in 0..2 {
            let reply = router.predict(&rows, &cols, &edges, None).unwrap();
            assert_eq!(reply.result.clone().unwrap(), want);
        }
        assert_eq!(router.stats().ejections.load(Ordering::SeqCst), 1);
        std::thread::sleep(Duration::from_millis(5));
        let reply = router.predict(&rows, &cols, &edges, None).unwrap();
        assert_eq!(reply.result.unwrap(), want);
        assert!(router.stats().reprobes.load(Ordering::SeqCst) >= 1, "cooldown elapsed: probed");
        assert_eq!(router.healthy_count(), 2, "recovered shard is healthy again");
    }

    #[test]
    fn all_shards_down_is_a_transport_error() {
        let shards: Vec<Box<dyn ShardBackend>> =
            vec![Box::new(MockShard::new("dead", usize::MAX))];
        let router = ShardRouter::new(shards, ShardRouterConfig::default()).unwrap();
        let (rows, cols, edges) = sample_batch();
        assert!(router.predict(&rows, &cols, &edges, None).is_err());
    }

    #[test]
    fn out_of_range_edge_is_typed_invalid() {
        let shards: Vec<Box<dyn ShardBackend>> = vec![Box::new(MockShard::new("s", 0))];
        let router = ShardRouter::new(shards, ShardRouterConfig::default()).unwrap();
        let reply = router.predict(&[vec![1.0]], &[vec![1.0]], &[(0, 7)], None).unwrap();
        assert!(matches!(reply.result, Err(PredictError::InvalidRequest(_))));
    }
}
