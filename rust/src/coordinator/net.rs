//! TCP front-end for the prediction server: a newline-delimited JSON
//! protocol over `std::net` — zero dependencies, like everything else in
//! the crate.
//!
//! One request per line, one response per line, in request order per
//! connection (clients may pipeline). The full wire grammar, every error
//! code, and a copy-pasteable `nc` session live in `docs/SERVING.md`; the
//! short form:
//!
//! ```text
//! → {"id": 1, "rows": [[...d floats...], ...], "cols": [[...r floats...], ...],
//!    "edges": [[0, 0], [1, 2]], "deadline_ms": 250}
//! ← {"generation": 0, "id": 1, "scores": [0.41, -1.73]}
//! ← {"error": {"code": "deadline_exceeded", "message": "...", "retryable": true},
//!    "generation": 0, "id": 2}
//! ```
//!
//! The design goal is that PR 8's robustness semantics **survive
//! serialization**: every [`PredictError`] variant maps onto a wire error
//! code (and back, in [`NetClient`]), deadlines ride the request and are
//! enforced by the same merge-time/score-time checks as in-process
//! traffic, and replies carry the scoring generation so hot swaps stay
//! observable across the wire. Scores are serialized with the shortest
//! round-trip `f64` encoding ([`Json::dump`]), so a remote client reads
//! back **bitwise-identical** values to an in-process
//! [`PredictServer::predict_blocking`] call.
//!
//! Threading: one acceptor thread; per connection, a reader thread (parse
//! + submit into the server's bounded queue) and a writer thread (drain
//! replies FIFO). Admission uses [`PredictServer::try_submit`], so a
//! saturated queue answers `overloaded` on the wire instead of stalling
//! the reader. Shutdown is a graceful drain: readers stop taking new
//! lines, writers flush every pending reply, the acceptor joins them all.

use std::io::{ErrorKind, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::server::{wait_reply, PredictError, PredictReply, PredictRequest, PredictServer};
use crate::util::json::Json;

/// How often blocked reads re-check the stop flag. Bounds shutdown drain
/// latency without burning CPU on idle connections.
const POLL_TICK: Duration = Duration::from_millis(50);

/// Extra client-side wait past a request's deadline for the typed
/// `deadline_exceeded` reply to cross the wire (mirrors the in-process
/// reply-drain slack).
const CLIENT_DRAIN_SLACK_MS: u64 = 5_000;

/// Network front-end configuration.
#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Bind address, e.g. `"127.0.0.1:7878"`. Port `0` asks the OS for a
    /// free port — read the result from [`NetServer::local_addr`].
    pub addr: String,
    /// Connection cap: further connects are answered with one `overloaded`
    /// error line and closed.
    pub max_connections: usize,
    /// Idle timeout per connection: a connection that sends no bytes for
    /// this long is closed. `0` disables.
    pub idle_timeout_ms: u64,
    /// Per-write timeout on response lines; a stuck peer loses its
    /// connection instead of wedging a writer thread.
    pub write_timeout_ms: u64,
    /// Request-line size cap in bytes. An oversized line is answered with
    /// a `bad_request` error and discarded through its terminating
    /// newline; the connection survives.
    pub max_line_bytes: usize,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            addr: "127.0.0.1:0".into(),
            max_connections: 256,
            idle_timeout_ms: 300_000,
            write_timeout_ms: 10_000,
            max_line_bytes: 4 << 20,
        }
    }
}

/// Wire-level counters, all monotone except `open_connections`.
#[derive(Debug, Default)]
pub struct NetStats {
    /// Connections accepted (excluding capped ones).
    pub connections: AtomicUsize,
    /// Currently open connections.
    pub open_connections: AtomicUsize,
    /// Connects refused by the connection cap.
    pub rejected_connections: AtomicUsize,
    /// Complete request lines received (well- or ill-formed).
    pub lines: AtomicUsize,
    /// Lines that failed at the wire layer: malformed JSON, invalid UTF-8,
    /// oversized, truncated by a mid-line disconnect.
    pub bad_lines: AtomicUsize,
    /// Response lines written (scores and errors alike).
    pub replies: AtomicUsize,
    /// Responses that carried an error object.
    pub wire_errors: AtomicUsize,
}

/// The TCP listener fronting one [`PredictServer`]. Owns the acceptor
/// thread; dropping (or [`NetServer::shutdown`]) stops accepting, drains
/// every in-flight reply, and joins all connection threads. The fronted
/// `PredictServer` is shared via `Arc`, so the owner can keep calling
/// [`PredictServer::swap_model`] / [`PredictServer::stats`] while the
/// listener serves.
pub struct NetServer {
    local: SocketAddr,
    stop: Arc<AtomicBool>,
    acceptor: Option<JoinHandle<()>>,
    stats: Arc<NetStats>,
}

impl NetServer {
    /// Bind and start serving. Fails on bind errors (address in use,
    /// permission) with the address in the message.
    pub fn start(server: Arc<PredictServer>, cfg: NetServerConfig) -> Result<NetServer, String> {
        let listener = TcpListener::bind(&cfg.addr)
            .map_err(|e| format!("cannot bind {}: {e}", cfg.addr))?;
        let local = listener
            .local_addr()
            .map_err(|e| format!("cannot read bound address: {e}"))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| format!("cannot set listener non-blocking: {e}"))?;
        let stop = Arc::new(AtomicBool::new(false));
        let stats = Arc::new(NetStats::default());
        let acceptor = {
            let stop = stop.clone();
            let stats = stats.clone();
            std::thread::Builder::new()
                .name("net-acceptor".into())
                .spawn(move || accept_loop(listener, server, cfg, stop, stats))
                .map_err(|e| format!("cannot spawn acceptor: {e}"))?
        };
        Ok(NetServer { local, stop, acceptor: Some(acceptor), stats })
    }

    /// The bound address (resolves port `0` binds).
    pub fn local_addr(&self) -> SocketAddr {
        self.local
    }

    /// Wire-level counters.
    pub fn stats(&self) -> &NetStats {
        &self.stats
    }

    /// Graceful drain: stop accepting, let every connection flush its
    /// pending replies, join all threads. The fronted [`PredictServer`] is
    /// left running — shut it down after this returns.
    pub fn shutdown(mut self) {
        self.stop_and_join();
    }

    fn stop_and_join(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop_and_join();
    }
}

fn accept_loop(
    listener: TcpListener,
    server: Arc<PredictServer>,
    cfg: NetServerConfig,
    stop: Arc<AtomicBool>,
    stats: Arc<NetStats>,
) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    loop {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                conns.retain(|h| !h.is_finished());
                if stats.open_connections.load(Ordering::SeqCst) >= cfg.max_connections {
                    stats.rejected_connections.fetch_add(1, Ordering::Relaxed);
                    refuse_connection(stream, cfg.write_timeout_ms);
                    continue;
                }
                stats.connections.fetch_add(1, Ordering::Relaxed);
                stats.open_connections.fetch_add(1, Ordering::SeqCst);
                let server = server.clone();
                let cfg = cfg.clone();
                let stop = stop.clone();
                let stats = stats.clone();
                let spawned = std::thread::Builder::new().name("net-conn".into()).spawn(
                    move || {
                        serve_connection(stream, &server, &cfg, &stop, &stats);
                        stats.open_connections.fetch_sub(1, Ordering::SeqCst);
                    },
                );
                match spawned {
                    Ok(h) => conns.push(h),
                    Err(_) => {
                        // Spawn failure: treat like a capped connection.
                        stats.open_connections.fetch_sub(1, Ordering::SeqCst);
                        stats.rejected_connections.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => std::thread::sleep(POLL_TICK),
            Err(_) => std::thread::sleep(POLL_TICK),
        }
    }
    for h in conns {
        let _ = h.join();
    }
}

/// Answer a capped connection with a single `overloaded` line and close.
fn refuse_connection(mut stream: TcpStream, write_timeout_ms: u64) {
    let _ = stream.set_write_timeout(Some(Duration::from_millis(write_timeout_ms.max(1))));
    let line = error_response(&Json::Null, "overloaded", "connection limit reached", true, 0);
    let _ = stream.write_all(line.as_bytes());
    let _ = stream.write_all(b"\n");
    let _ = stream.shutdown(Shutdown::Both);
}

/// What the reader hands the writer, in request order.
enum Outgoing {
    /// A response already built at parse time (wire errors, info replies).
    Ready(String),
    /// A submitted predict request: the writer waits for its reply (bounded
    /// by the deadline plus drain slack) and serializes it.
    Pending { id: Json, rx: Receiver<PredictReply>, deadline: Option<Instant> },
}

fn serve_connection(
    stream: TcpStream,
    server: &PredictServer,
    cfg: &NetServerConfig,
    stop: &AtomicBool,
    stats: &Arc<NetStats>,
) {
    let _ = stream.set_read_timeout(Some(POLL_TICK));
    let _ = stream.set_write_timeout(Some(Duration::from_millis(cfg.write_timeout_ms.max(1))));
    let (out_tx, out_rx) = channel::<Outgoing>();
    let conn_dead = Arc::new(AtomicBool::new(false));
    let writer = {
        let stream = stream.try_clone();
        let conn_dead = conn_dead.clone();
        let stats = stats.clone();
        match stream {
            Ok(s) => std::thread::Builder::new()
                .name("net-writer".into())
                .spawn(move || writer_loop(s, out_rx, conn_dead, stats))
                .ok(),
            Err(_) => None,
        }
    };
    if writer.is_some() {
        reader_loop(&stream, server, cfg, stop, stats, &out_tx, &conn_dead);
    }
    // Dropping the sender ends the writer after it drains pending replies.
    drop(out_tx);
    if let Some(h) = writer {
        let _ = h.join();
    }
    let _ = stream.shutdown(Shutdown::Both);
}

fn writer_loop(
    mut stream: TcpStream,
    rx: Receiver<Outgoing>,
    conn_dead: Arc<AtomicBool>,
    stats: Arc<NetStats>,
) {
    while let Ok(item) = rx.recv() {
        let line = match item {
            Outgoing::Ready(line) => line,
            Outgoing::Pending { id, rx, deadline } => {
                let reply = wait_reply(&rx, deadline).unwrap_or_else(|e| PredictReply {
                    result: Err(e),
                    generation: 0,
                });
                if reply.result.is_err() {
                    stats.wire_errors.fetch_add(1, Ordering::Relaxed);
                }
                reply_response(&id, &reply)
            }
        };
        if conn_dead.load(Ordering::SeqCst) {
            continue; // peer is gone; keep draining so reply channels close cleanly
        }
        if stream.write_all(line.as_bytes()).is_err()
            || stream.write_all(b"\n").is_err()
            || stream.flush().is_err()
        {
            conn_dead.store(true, Ordering::SeqCst);
        }
    }
}

fn reader_loop(
    stream: &TcpStream,
    server: &PredictServer,
    cfg: &NetServerConfig,
    stop: &AtomicBool,
    stats: &NetStats,
    out: &Sender<Outgoing>,
    conn_dead: &AtomicBool,
) {
    let mut rd = LineReader::new(stream, cfg.max_line_bytes, cfg.idle_timeout_ms);
    loop {
        if conn_dead.load(Ordering::SeqCst) {
            return;
        }
        let raw = match rd.next_line(stop) {
            LineOutcome::Line(raw) => raw,
            LineOutcome::TooLong => {
                stats.lines.fetch_add(1, Ordering::Relaxed);
                stats.bad_lines.fetch_add(1, Ordering::Relaxed);
                send_error(out, stats, &Json::Null, "bad_request", "request line too long", server);
                continue;
            }
            LineOutcome::TruncatedEof => {
                // Mid-line disconnect: nothing to answer (the peer is gone),
                // but the protocol violation is counted.
                stats.bad_lines.fetch_add(1, Ordering::Relaxed);
                return;
            }
            LineOutcome::Eof | LineOutcome::Stopped | LineOutcome::IdleTimeout => return,
        };
        stats.lines.fetch_add(1, Ordering::Relaxed);
        let text = match String::from_utf8(raw) {
            Ok(t) => t,
            Err(_) => {
                stats.bad_lines.fetch_add(1, Ordering::Relaxed);
                send_error(out, stats, &Json::Null, "bad_request", "request is not UTF-8", server);
                continue;
            }
        };
        if text.trim().is_empty() {
            continue; // blank keep-alive lines are ignored
        }
        let parsed = match Json::parse(&text) {
            Ok(v) => v,
            Err(e) => {
                stats.bad_lines.fetch_add(1, Ordering::Relaxed);
                send_error(
                    out,
                    stats,
                    &Json::Null,
                    "bad_request",
                    &format!("malformed JSON: {e}"),
                    server,
                );
                continue;
            }
        };
        handle_request(parsed, server, out, stats);
    }
}

/// Decode one parsed request object, submit or answer it, and enqueue the
/// (eventual) response — always exactly one response per line, in order.
fn handle_request(v: Json, server: &PredictServer, out: &Sender<Outgoing>, stats: &NetStats) {
    let id = v.get("id").cloned().unwrap_or(Json::Null);
    if v.as_obj().is_none() {
        stats.bad_lines.fetch_add(1, Ordering::Relaxed);
        send_error(out, stats, &Json::Null, "bad_request", "request must be a JSON object", server);
        return;
    }
    match v.get("op").map(|o| o.as_str()) {
        None | Some(Some("predict")) => {}
        Some(Some("info")) => {
            let (d, r) = server.feature_dims();
            let generation = server.stats().generation.load(Ordering::Relaxed);
            let body = Json::obj(vec![
                ("generation", Json::from(generation)),
                ("id", id.clone()),
                (
                    "info",
                    Json::obj(vec![
                        ("dims", Json::Arr(vec![Json::from(d), Json::from(r)])),
                        ("generation", Json::from(generation)),
                    ]),
                ),
            ]);
            stats.replies.fetch_add(1, Ordering::Relaxed);
            let _ = out.send(Outgoing::Ready(dump_or_internal(&id, body, generation)));
            return;
        }
        Some(Some(other)) => {
            let msg = format!("unknown op {other:?} (expected \"predict\" or \"info\")");
            send_error(out, stats, &id, "invalid_request", &msg, server);
            return;
        }
        Some(None) => {
            send_error(out, stats, &id, "invalid_request", "\"op\" must be a string", server);
            return;
        }
    }
    let (rows, cols, edges, deadline_ms) = match decode_predict(&v) {
        Ok(parts) => parts,
        Err(msg) => {
            send_error(out, stats, &id, "invalid_request", &msg, server);
            return;
        }
    };
    let (reply_tx, reply_rx) = channel();
    let mut req = PredictRequest::new(rows, cols, edges, reply_tx);
    match deadline_ms {
        Some(ms) => req = req.with_deadline_ms(ms),
        None if server.request_timeout_ms() > 0 => {
            req = req.with_deadline_ms(server.request_timeout_ms());
        }
        None => {}
    }
    let deadline = req.deadline;
    stats.replies.fetch_add(1, Ordering::Relaxed);
    // Enqueue the pending slot BEFORE submission so responses keep request
    // order; if admission refuses the request, `try_submit` has already
    // answered the reply channel and the writer serializes the typed error.
    let _ = out.send(Outgoing::Pending { id, rx: reply_rx, deadline });
    let _ = server.try_submit(req);
}

/// Pull `rows` / `cols` / `edges` / `deadline_ms` out of a request object
/// with precise error messages. Unknown fields are ignored (forward
/// compatibility); semantic validation against the model's feature dims is
/// the server's job and arrives as `invalid_request` from the merger.
#[allow(clippy::type_complexity)]
fn decode_predict(
    v: &Json,
) -> Result<(Vec<Vec<f64>>, Vec<Vec<f64>>, Vec<(u32, u32)>, Option<u64>), String> {
    let feature_rows = |key: &str| -> Result<Vec<Vec<f64>>, String> {
        let arr = v
            .get(key)
            .ok_or_else(|| format!("missing field {key:?}"))?
            .as_arr()
            .ok_or_else(|| format!("{key:?} must be an array of feature rows"))?;
        arr.iter()
            .enumerate()
            .map(|(i, row)| {
                let row =
                    row.as_arr().ok_or_else(|| format!("{key}[{i}] must be a number array"))?;
                row.iter()
                    .map(|x| x.as_f64().ok_or_else(|| format!("{key}[{i}] holds a non-number")))
                    .collect()
            })
            .collect()
    };
    let rows = feature_rows("rows")?;
    let cols = feature_rows("cols")?;
    let edges = v
        .get("edges")
        .ok_or("missing field \"edges\"")?
        .as_arr()
        .ok_or("\"edges\" must be an array of [start, end] pairs")?
        .iter()
        .enumerate()
        .map(|(i, pair)| {
            let pair = pair.as_arr().filter(|p| p.len() == 2).ok_or_else(|| {
                format!("edges[{i}] must be a [start, end] pair")
            })?;
            let idx = |side: usize| -> Result<u32, String> {
                pair[side]
                    .as_u64()
                    .and_then(|n| u32::try_from(n).ok())
                    .ok_or_else(|| format!("edges[{i}] index out of range"))
            };
            Ok((idx(0)?, idx(1)?))
        })
        .collect::<Result<Vec<(u32, u32)>, String>>()?;
    let deadline_ms = match v.get("deadline_ms") {
        None | Some(Json::Null) => None,
        Some(n) => Some(n.as_u64().ok_or("\"deadline_ms\" must be a non-negative integer")?),
    };
    Ok((rows, cols, edges, deadline_ms))
}

fn send_error(
    out: &Sender<Outgoing>,
    stats: &NetStats,
    id: &Json,
    code: &str,
    message: &str,
    server: &PredictServer,
) {
    let generation = server.stats().generation.load(Ordering::Relaxed);
    stats.replies.fetch_add(1, Ordering::Relaxed);
    stats.wire_errors.fetch_add(1, Ordering::Relaxed);
    let _ = out.send(Outgoing::Ready(error_response(id, code, message, false, generation)));
}

/// Serialize a response body, downgrading non-encodable payloads (scores
/// containing NaN/inf) to a typed error line rather than dropping the
/// response and desynchronizing the stream.
fn dump_or_internal(id: &Json, body: Json, generation: u64) -> String {
    body.dump().unwrap_or_else(|e| {
        error_response(
            id,
            "invalid_request",
            &format!("response not JSON-encodable: {e}"),
            false,
            generation,
        )
    })
}

/// The wire error code for a typed [`PredictError`].
pub fn wire_code(e: &PredictError) -> &'static str {
    match e {
        PredictError::InvalidRequest(_) => "invalid_request",
        PredictError::DeadlineExceeded => "deadline_exceeded",
        PredictError::Overloaded => "overloaded",
        PredictError::ShuttingDown => "shutting_down",
    }
}

/// Whether a retry against the same (or another) server can succeed.
/// Matches the retryability documented on [`PredictError`]: overload and
/// shutdown are transient, a deadline can be retried with a fresh budget,
/// an invalid request never heals on its own.
pub fn wire_retryable(e: &PredictError) -> bool {
    !matches!(e, PredictError::InvalidRequest(_))
}

/// Map a wire error code back to the typed error ([`NetClient`] uses this
/// so remote callers see the same `Result<_, PredictError>` surface as
/// in-process ones). `bad_request` — the wire-only code for lines that
/// never parsed far enough to have semantics — maps to `InvalidRequest`.
pub fn error_from_wire(code: &str, message: &str) -> Option<PredictError> {
    match code {
        "invalid_request" | "bad_request" => {
            Some(PredictError::InvalidRequest(message.to_string()))
        }
        "deadline_exceeded" => Some(PredictError::DeadlineExceeded),
        "overloaded" => Some(PredictError::Overloaded),
        "shutting_down" => Some(PredictError::ShuttingDown),
        _ => None,
    }
}

fn error_response(id: &Json, code: &str, message: &str, retryable: bool, generation: u64) -> String {
    let body = Json::obj(vec![
        (
            "error",
            Json::obj(vec![
                ("code", Json::from(code)),
                ("message", Json::from(message)),
                ("retryable", Json::from(retryable)),
            ]),
        ),
        ("generation", Json::from(generation)),
        ("id", id.clone()),
    ]);
    body.dump().expect("error responses contain no non-finite numbers")
}

/// Serialize a [`PredictReply`] (scores or typed error) as a response line.
fn reply_response(id: &Json, reply: &PredictReply) -> String {
    match &reply.result {
        Ok(scores) => {
            let body = Json::obj(vec![
                ("generation", Json::from(reply.generation)),
                ("id", id.clone()),
                ("scores", Json::num_arr(scores)),
            ]);
            dump_or_internal(id, body, reply.generation)
        }
        Err(e) => {
            error_response(id, wire_code(e), &e.to_string(), wire_retryable(e), reply.generation)
        }
    }
}

/// Build a predict request line body (shared by [`NetClient`], the shard
/// router, and `bench_net`).
pub fn encode_request(
    id: u64,
    rows: &[Vec<f64>],
    cols: &[Vec<f64>],
    edges: &[(u32, u32)],
    deadline_ms: Option<u64>,
) -> Json {
    let features = |rows: &[Vec<f64>]| {
        Json::Arr(rows.iter().map(|r| Json::num_arr(r)).collect())
    };
    let mut pairs = vec![
        ("cols", features(cols)),
        (
            "edges",
            Json::Arr(
                edges
                    .iter()
                    .map(|&(s, e)| {
                        Json::Arr(vec![Json::from(s as usize), Json::from(e as usize)])
                    })
                    .collect(),
            ),
        ),
        ("id", Json::from(id)),
        ("rows", features(rows)),
    ];
    if let Some(ms) = deadline_ms {
        pairs.push(("deadline_ms", Json::from(ms)));
    }
    Json::obj(pairs)
}

/// Parse a response line into the typed reply. Transport-shaped problems
/// (unknown error code, missing fields) come back as `Err(String)` —
/// distinct from a typed [`PredictError`], which means the *server*
/// answered.
pub fn decode_reply(v: &Json) -> Result<PredictReply, String> {
    let generation = v.get("generation").and_then(Json::as_u64).unwrap_or(0);
    if let Some(scores) = v.get("scores") {
        let scores = scores
            .as_arr()
            .ok_or("\"scores\" must be an array")?
            .iter()
            .map(|x| x.as_f64().ok_or_else(|| "non-number score".to_string()))
            .collect::<Result<Vec<f64>, String>>()?;
        return Ok(PredictReply { result: Ok(scores), generation });
    }
    if let Some(err) = v.get("error") {
        let code = err.get("code").and_then(Json::as_str).ok_or("error without code")?;
        let message = err.get("message").and_then(Json::as_str).unwrap_or("");
        let typed = error_from_wire(code, message)
            .ok_or_else(|| format!("unknown wire error code {code:?}"))?;
        return Ok(PredictReply { result: Err(typed), generation });
    }
    Err("response carries neither scores nor error".into())
}

/// A blocking client for the line protocol: connect, pipeline requests,
/// read responses in order. Used by the CLI demo traffic, the shard
/// router's remote backends, the loopback tests, and `bench_net`.
pub struct NetClient {
    stream: TcpStream,
    buf: Vec<u8>,
    next_id: u64,
    /// Baseline receive timeout for requests without a deadline.
    pub recv_timeout_ms: u64,
}

impl NetClient {
    /// Connect with a default 30 s receive timeout.
    pub fn connect(addr: &str) -> Result<NetClient, String> {
        let stream =
            TcpStream::connect(addr).map_err(|e| format!("cannot connect to {addr}: {e}"))?;
        stream
            .set_read_timeout(Some(POLL_TICK))
            .map_err(|e| format!("cannot set read timeout: {e}"))?;
        stream
            .set_write_timeout(Some(Duration::from_millis(10_000)))
            .map_err(|e| format!("cannot set write timeout: {e}"))?;
        let _ = stream.set_nodelay(true);
        Ok(NetClient { stream, buf: Vec::new(), next_id: 1, recv_timeout_ms: 30_000 })
    }

    /// Score a batch over the wire. Returns the typed reply (scores or
    /// [`PredictError`]) on a protocol-level success; `Err(String)` means
    /// transport failure — connection refused/reset, response timeout, or
    /// an unparseable response.
    pub fn predict(
        &mut self,
        rows: &[Vec<f64>],
        cols: &[Vec<f64>],
        edges: &[(u32, u32)],
        deadline_ms: Option<u64>,
    ) -> Result<PredictReply, String> {
        let id = self.next_id;
        self.next_id += 1;
        let line = encode_request(id, rows, cols, edges, deadline_ms)
            .dump()
            .map_err(|e| format!("request not JSON-encodable: {e}"))?;
        self.send_raw(&line)?;
        let wait = deadline_ms.map_or(self.recv_timeout_ms, |ms| ms + CLIENT_DRAIN_SLACK_MS);
        let v = self.recv_json(wait)?;
        let echoed = v.get("id").and_then(Json::as_u64);
        if echoed != Some(id) {
            return Err(format!("response id {echoed:?} does not echo request id {id}"));
        }
        decode_reply(&v)
    }

    /// Query the server's feature dims and current generation (`op: info`).
    pub fn info(&mut self) -> Result<((usize, usize), u64), String> {
        let id = self.next_id;
        self.next_id += 1;
        let line = Json::obj(vec![("id", Json::from(id)), ("op", Json::from("info"))])
            .dump()
            .expect("info request is finite");
        self.send_raw(&line)?;
        let v = self.recv_json(self.recv_timeout_ms)?;
        let info = v.get("info").ok_or_else(|| {
            v.get("error")
                .and_then(|e| e.get("message"))
                .and_then(Json::as_str)
                .map_or("response carries no info".to_string(), |m| format!("info refused: {m}"))
        })?;
        let dims = info.get("dims").and_then(Json::as_arr).ok_or("info without dims")?;
        let d = dims.first().and_then(Json::as_usize).ok_or("bad dims")?;
        let r = dims.get(1).and_then(Json::as_usize).ok_or("bad dims")?;
        let generation = info.get("generation").and_then(Json::as_u64).unwrap_or(0);
        Ok(((d, r), generation))
    }

    /// Write one raw line (newline appended). Public so protocol tests can
    /// send deliberately malformed traffic.
    pub fn send_raw(&mut self, line: &str) -> Result<(), String> {
        self.stream
            .write_all(line.as_bytes())
            .and_then(|_| self.stream.write_all(b"\n"))
            .and_then(|_| self.stream.flush())
            .map_err(|e| format!("send failed: {e}"))
    }

    /// Write raw bytes verbatim (no newline appended) — for tests that
    /// need invalid UTF-8 or truncated lines on the wire.
    pub fn send_bytes(&mut self, bytes: &[u8]) -> Result<(), String> {
        self.stream
            .write_all(bytes)
            .and_then(|_| self.stream.flush())
            .map_err(|e| format!("send failed: {e}"))
    }

    /// Read one response line within `timeout_ms` and parse it as JSON.
    pub fn recv_json(&mut self, timeout_ms: u64) -> Result<Json, String> {
        let line = self.recv_line(timeout_ms)?;
        Json::parse(&line).map_err(|e| format!("unparseable response: {e}"))
    }

    /// Read one raw response line within `timeout_ms`.
    pub fn recv_line(&mut self, timeout_ms: u64) -> Result<String, String> {
        let deadline = Instant::now() + Duration::from_millis(timeout_ms);
        loop {
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                let mut line: Vec<u8> = self.buf.drain(..=pos).collect();
                line.pop(); // the newline
                return String::from_utf8(line).map_err(|_| "response is not UTF-8".into());
            }
            if Instant::now() >= deadline {
                return Err("timed out waiting for response".into());
            }
            let mut chunk = [0u8; 16 * 1024];
            match self.stream.read(&mut chunk) {
                Ok(0) => return Err("connection closed by server".into()),
                Ok(n) => self.buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {}
                Err(e) => return Err(format!("receive failed: {e}")),
            }
        }
    }
}

/// What one blocking line read produced.
enum LineOutcome {
    /// A complete line (newline stripped), within the size cap.
    Line(Vec<u8>),
    /// A line exceeded the cap; it has been discarded through its newline.
    TooLong,
    /// Clean EOF at a line boundary.
    Eof,
    /// EOF with unterminated bytes pending — a truncated request.
    TruncatedEof,
    /// The server's stop flag was observed.
    Stopped,
    /// No bytes for the configured idle timeout.
    IdleTimeout,
}

/// Incremental line reader over a non-blocking-ish socket (short read
/// timeouts as poll ticks): accumulates bytes, hands out newline-delimited
/// lines, enforces the size cap by switching into discard mode until the
/// offending line's newline passes.
struct LineReader<'a> {
    stream: &'a TcpStream,
    buf: Vec<u8>,
    max_line: usize,
    idle_timeout: Option<Duration>,
    last_activity: Instant,
    discarding: bool,
}

impl<'a> LineReader<'a> {
    fn new(stream: &'a TcpStream, max_line: usize, idle_timeout_ms: u64) -> LineReader<'a> {
        LineReader {
            stream,
            buf: Vec::new(),
            max_line,
            idle_timeout: (idle_timeout_ms > 0).then(|| Duration::from_millis(idle_timeout_ms)),
            last_activity: Instant::now(),
            discarding: false,
        }
    }

    fn next_line(&mut self, stop: &AtomicBool) -> LineOutcome {
        loop {
            // Drain complete lines already buffered before touching the
            // socket again.
            if let Some(pos) = self.buf.iter().position(|&b| b == b'\n') {
                let mut line: Vec<u8> = self.buf.drain(..=pos).collect();
                line.pop();
                if self.discarding {
                    self.discarding = false;
                    return LineOutcome::TooLong;
                }
                if line.len() > self.max_line {
                    return LineOutcome::TooLong;
                }
                return LineOutcome::Line(line);
            }
            if self.buf.len() > self.max_line && !self.discarding {
                // Stop buffering a line that can never be served; remember
                // to report it once its newline (or EOF) arrives.
                self.discarding = true;
                self.buf.clear();
            } else if self.discarding {
                self.buf.clear();
            }
            if stop.load(Ordering::SeqCst) {
                return LineOutcome::Stopped;
            }
            if let Some(limit) = self.idle_timeout {
                if self.last_activity.elapsed() >= limit {
                    return LineOutcome::IdleTimeout;
                }
            }
            let mut chunk = [0u8; 16 * 1024];
            let mut sock = self.stream; // `Read` is implemented for `&TcpStream`
            match sock.read(&mut chunk) {
                Ok(0) => {
                    return if self.buf.is_empty() && !self.discarding {
                        LineOutcome::Eof
                    } else {
                        LineOutcome::TruncatedEof
                    };
                }
                Ok(n) => {
                    self.buf.extend_from_slice(&chunk[..n]);
                    self.last_activity = Instant::now();
                }
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {}
                Err(_) => return LineOutcome::TruncatedEof,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wire_codes_round_trip_every_variant() {
        let variants = [
            PredictError::InvalidRequest("dims".into()),
            PredictError::DeadlineExceeded,
            PredictError::Overloaded,
            PredictError::ShuttingDown,
        ];
        for e in variants {
            let code = wire_code(&e);
            let back = error_from_wire(code, &e.to_string()).expect("known code");
            match (&e, &back) {
                (PredictError::InvalidRequest(_), PredictError::InvalidRequest(_)) => {}
                _ => assert_eq!(&e, &back, "code {code} must round-trip"),
            }
        }
        assert!(error_from_wire("no_such_code", "").is_none());
        assert!(matches!(
            error_from_wire("bad_request", "junk"),
            Some(PredictError::InvalidRequest(_))
        ));
        assert!(!wire_retryable(&PredictError::InvalidRequest("x".into())));
        assert!(wire_retryable(&PredictError::Overloaded));
        assert!(wire_retryable(&PredictError::ShuttingDown));
        assert!(wire_retryable(&PredictError::DeadlineExceeded));
    }

    #[test]
    fn request_encoding_decodes_structurally() {
        let rows = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let cols = vec![vec![0.5]];
        let edges = vec![(0, 0), (1, 0)];
        let v = encode_request(7, &rows, &cols, &edges, Some(250));
        let (drows, dcols, dedges, dl) = decode_predict(&v).expect("round trip");
        assert_eq!(drows, rows);
        assert_eq!(dcols, cols);
        assert_eq!(dedges, edges);
        assert_eq!(dl, Some(250));
        assert_eq!(v.get("id").and_then(Json::as_u64), Some(7));
    }

    #[test]
    fn decode_predict_rejects_structural_garbage() {
        let bad = [
            r#"{"cols": [], "edges": []}"#,                                  // missing rows
            r#"{"rows": 3, "cols": [], "edges": []}"#,                       // rows not array
            r#"{"rows": [[1]], "cols": [["x"]], "edges": []}"#,              // non-number feature
            r#"{"rows": [[1]], "cols": [[1]], "edges": [[0]]}"#,             // 1-ary edge
            r#"{"rows": [[1]], "cols": [[1]], "edges": [[0, -1]]}"#,         // negative index
            r#"{"rows": [[1]], "cols": [[1]], "edges": [[0, 4294967296]]}"#, // > u32
            r#"{"rows": [[1]], "cols": [[1]], "edges": [[0,0]], "deadline_ms": -5}"#,
        ];
        for src in bad {
            let v = Json::parse(src).unwrap();
            assert!(decode_predict(&v).is_err(), "must reject {src}");
        }
        // unknown fields are ignored
        let v = Json::parse(
            r#"{"rows": [[1]], "cols": [[1]], "edges": [[0,0]], "future_knob": {"x": 1}}"#,
        )
        .unwrap();
        assert!(decode_predict(&v).is_ok());
    }

    #[test]
    fn reply_serialization_round_trips() {
        let ok = PredictReply { result: Ok(vec![0.125, -3.5]), generation: 4 };
        let line = reply_response(&Json::from(9_u64), &ok);
        let back = decode_reply(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, ok);

        let err = PredictReply { result: Err(PredictError::Overloaded), generation: 2 };
        let line = reply_response(&Json::Null, &err);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str), Some("overloaded"));
        assert_eq!(
            v.get("error").and_then(|e| e.get("retryable")),
            Some(&Json::Bool(true))
        );
        let back = decode_reply(&v).unwrap();
        assert_eq!(back.result, Err(PredictError::Overloaded));
        assert_eq!(back.generation, 2);
    }

    #[test]
    fn non_finite_scores_become_a_typed_error_line() {
        let reply = PredictReply { result: Ok(vec![f64::NAN]), generation: 1 };
        let line = reply_response(&Json::from(3_u64), &reply);
        let v = Json::parse(&line).expect("still a valid response line");
        assert!(v.get("scores").is_none());
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("invalid_request")
        );
    }
}
