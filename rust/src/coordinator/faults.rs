//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a seeded, repeatable schedule of failures the
//! [`PredictServer`](super::PredictServer) trips on purpose — worker panics
//! on the Nth merged batch, stalls that push a batch past its requests'
//! deadlines, and queue-admission rejections — so the fault-tolerance
//! guarantees (supervised respawn, deadline shedding, typed overload
//! errors) are provable by ordinary integration tests instead of depending
//! on timing luck.
//!
//! The plan is compiled unconditionally (a `cfg(test)` gate would hide it
//! from the `rust/tests/` integration crates, which build this library
//! without `cfg(test)`), but an empty plan — what
//! [`PredictServer::start`](super::PredictServer::start) installs — costs
//! one branch per hook and allocates nothing. Injection applies to requests
//! entering through the server's own submit APIs and to batches reaching
//! the scoring pool; traffic submitted through a raw
//! [`sender`](super::PredictServer::sender) handle bypasses the admission
//! hook.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::util::rng::Pcg32;

/// A deterministic schedule of injected serving faults. Build one with the
/// chained setters and pass it to
/// [`PredictServer::start_with_faults`](super::PredictServer::start_with_faults):
///
/// ```
/// use kronvt::coordinator::FaultPlan;
///
/// // panic the worker scoring batch 1, stall batch 3 for 50ms, and reject
/// // the 2nd admitted request at the queue
/// let plan = FaultPlan::seeded(7).panic_on_batch(1).sleep_on_batch(3, 50).reject_request(2);
/// assert!(!plan.is_empty());
/// ```
#[derive(Debug, Default)]
pub struct FaultPlan {
    /// 1-based ordinals of merged batches whose scoring worker panics.
    panic_batches: Vec<u64>,
    /// Per-batch panic probability, drawn from the seeded RNG.
    panic_probability: f64,
    /// 1-based batch ordinals that stall before scoring, and for how long
    /// (milliseconds) — the straggler / deadline-expiry injection.
    sleep_batches: Vec<(u64, u64)>,
    /// 1-based ordinals of admitted requests rejected at the queue.
    reject_requests: Vec<u64>,
    rng: Option<Mutex<Pcg32>>,
    batch_seq: AtomicU64,
    request_seq: AtomicU64,
}

impl FaultPlan {
    /// The empty plan: every hook is a no-op (what a production server runs).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// An empty plan carrying a seeded [`Pcg32`] for probabilistic triggers
    /// ([`FaultPlan::panic_with_probability`]); the deterministic Nth-event
    /// triggers work with or without the seed.
    pub fn seeded(seed: u64) -> FaultPlan {
        FaultPlan { rng: Some(Mutex::new(Pcg32::seeded(seed))), ..Default::default() }
    }

    /// Panic the scoring worker on the `n`th merged batch (1-based).
    pub fn panic_on_batch(mut self, n: u64) -> FaultPlan {
        self.panic_batches.push(n);
        self
    }

    /// Panic the scoring worker on each batch with probability `p` (needs a
    /// [`FaultPlan::seeded`] plan; a plan without an RNG never trips this).
    pub fn panic_with_probability(mut self, p: f64) -> FaultPlan {
        self.panic_probability = p;
        self
    }

    /// Stall the `n`th merged batch (1-based) for `ms` milliseconds before
    /// scoring — long enough and the batch's requests expire their
    /// deadlines, proving score-time shedding.
    pub fn sleep_on_batch(mut self, n: u64, ms: u64) -> FaultPlan {
        self.sleep_batches.push((n, ms));
        self
    }

    /// Reject the `n`th admitted request (1-based) at the queue, as if the
    /// bounded queue were full — the server answers it `Overloaded`.
    pub fn reject_request(mut self, n: u64) -> FaultPlan {
        self.reject_requests.push(n);
        self
    }

    /// True when no trigger is armed — the hooks reduce to one branch.
    pub fn is_empty(&self) -> bool {
        self.panic_batches.is_empty()
            && self.sleep_batches.is_empty()
            && self.reject_requests.is_empty()
            && self.panic_probability == 0.0
    }

    /// Queue-admission hook: `true` tells the server to reject this request
    /// as `Overloaded`. Called once per request admitted through the
    /// server's submit APIs.
    pub fn trip_queue_rejection(&self) -> bool {
        if self.reject_requests.is_empty() {
            return false;
        }
        let n = self.request_seq.fetch_add(1, Ordering::Relaxed) + 1;
        self.reject_requests.contains(&n)
    }

    /// Batch-start hook: may stall (straggler injection) and then panic
    /// (worker-crash injection) according to the plan. Called by the scoring
    /// worker before it touches the batch, so a planned panic costs exactly
    /// that batch and nothing else.
    pub fn trip_batch_start(&self) {
        if self.is_empty() {
            return;
        }
        let n = self.batch_seq.fetch_add(1, Ordering::Relaxed) + 1;
        if let Some(&(_, ms)) = self.sleep_batches.iter().find(|&&(b, _)| b == n) {
            std::thread::sleep(Duration::from_millis(ms));
        }
        assert!(!self.panic_batches.contains(&n), "fault injection: planned panic on batch {n}");
        if self.panic_probability > 0.0 {
            if let Some(rng) = &self.rng {
                let trip = rng
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .bernoulli(self.panic_probability);
                assert!(!trip, "fault injection: probabilistic panic on batch {n}");
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_trips_nothing() {
        let plan = FaultPlan::none();
        assert!(plan.is_empty());
        for _ in 0..100 {
            assert!(!plan.trip_queue_rejection());
            plan.trip_batch_start(); // must not panic or sleep
        }
    }

    #[test]
    fn nth_request_rejection_is_deterministic() {
        let plan = FaultPlan::seeded(3).reject_request(2).reject_request(4);
        let trips: Vec<bool> = (0..6).map(|_| plan.trip_queue_rejection()).collect();
        assert_eq!(trips, [false, true, false, true, false, false]);
    }

    #[test]
    fn planned_batch_panic_fires_on_its_ordinal_only() {
        let plan = FaultPlan::seeded(4).panic_on_batch(3);
        plan.trip_batch_start(); // batch 1
        plan.trip_batch_start(); // batch 2
        let crash = std::thread::spawn(move || plan.trip_batch_start()); // batch 3
        assert!(crash.join().is_err(), "batch 3 must panic");
    }

    #[test]
    fn probabilistic_panics_are_reproducible_across_same_seed_plans() {
        let outcomes = |seed: u64| -> Vec<bool> {
            let plan = std::sync::Arc::new(FaultPlan::seeded(seed).panic_with_probability(0.3));
            (0..32)
                .map(|_| {
                    let plan = plan.clone();
                    std::thread::spawn(move || plan.trip_batch_start()).join().is_err()
                })
                .collect()
        };
        // same seed → the same batches panic, run after run
        let a = outcomes(9);
        assert_eq!(a, outcomes(9));
        let trips = a.iter().filter(|&&p| p).count();
        assert!((1..32).contains(&trips), "p=0.3 over 32 draws should mix: {trips} trips");
    }
}
