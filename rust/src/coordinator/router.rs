//! Compute routing: native GVT loops (L3) vs the PJRT dense-GEMM path
//! (L1/L2 artifacts).
//!
//! Algorithm 1 already branches on `ae + df < ce + bf`; the router lifts the
//! same idea one level up. The native path costs `O((m+q)·n)` and exploits
//! edge sparsity; the dense artifact path costs `O(n + mq(m+q))` regardless
//! of sparsity but runs as GEMMs (MXU on a real TPU). The router picks per
//! call from the flop model, preferring native when no artifact bucket
//! covers the shape — so the system degrades gracefully to pure Rust.

use crate::gvt::complexity;
use crate::gvt::{gvt_apply_into, KronIndex, WorkspacePool};
use crate::linalg::Matrix;
use crate::runtime::ArtifactRegistry;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Which execution path a matvec takes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Route {
    /// Cache-blocked CPU loops of Algorithm 1.
    NativeGvt,
    /// AOT-compiled scatter→GEMM→gather artifact on PJRT.
    PjrtDense,
}

/// Router configuration.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Multiplicative weight on the dense path's flop count. Dense GEMM
    /// flops are far cheaper per flop than the native path's scattered
    /// AXPY/dot flops (contiguous, vectorized, f32 — and MXU-bound on a real
    /// TPU), so this is < 1; it also absorbs PJRT dispatch + f64↔f32
    /// conversion overhead. Calibrated against measurements in
    /// EXPERIMENTS.md §Perf. Larger values bias toward the native path.
    pub pjrt_overhead: f64,
    /// Force a specific route (None = decide by cost model).
    pub force: Option<Route>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { pjrt_overhead: 0.35, force: None }
    }
}

/// Per-route call counters (observability).
#[derive(Debug, Default, Clone, Copy)]
pub struct RouteStats {
    /// Matvecs executed by the native GVT loops.
    pub native_calls: usize,
    /// Matvecs executed by PJRT artifacts.
    pub pjrt_calls: usize,
}

/// The router itself. Owns an optional artifact registry; without one every
/// call routes native.
///
/// Scratch buffers come from a [`WorkspacePool`] and the counters are
/// atomics, so routing state never blocks concurrent use (the registry
/// itself remains the only non-`Sync` member, and only when attached).
pub struct Router {
    registry: Option<ArtifactRegistry>,
    cfg: RouterConfig,
    native_calls: AtomicUsize,
    pjrt_calls: AtomicUsize,
    pool: WorkspacePool,
}

impl Router {
    /// Router with artifacts (PJRT path available).
    pub fn with_registry(registry: ArtifactRegistry, cfg: RouterConfig) -> Router {
        Router {
            registry: Some(registry),
            cfg,
            native_calls: AtomicUsize::new(0),
            pjrt_calls: AtomicUsize::new(0),
            pool: WorkspacePool::new(),
        }
    }

    /// Native-only router.
    pub fn native_only(cfg: RouterConfig) -> Router {
        Router {
            registry: None,
            cfg,
            native_calls: AtomicUsize::new(0),
            pjrt_calls: AtomicUsize::new(0),
            pool: WorkspacePool::new(),
        }
    }

    /// Open the default registry if present, else run native-only.
    pub fn auto<P: AsRef<std::path::Path>>(artifact_dir: P, cfg: RouterConfig) -> Router {
        if ArtifactRegistry::available(&artifact_dir) {
            match ArtifactRegistry::open(&artifact_dir) {
                Ok(reg) => return Router::with_registry(reg, cfg),
                Err(err) => {
                    crate::log_warn!("artifact registry unavailable ({err}); routing native");
                }
            }
        }
        Router::native_only(cfg)
    }

    /// Per-route call counters so far.
    pub fn stats(&self) -> RouteStats {
        RouteStats {
            native_calls: self.native_calls.load(Ordering::Relaxed),
            pjrt_calls: self.pjrt_calls.load(Ordering::Relaxed),
        }
    }

    /// Whether a PJRT artifact registry is attached.
    pub fn has_pjrt(&self) -> bool {
        self.registry.is_some()
    }

    /// Decide the route for the square training matvec `R(G⊗K)Rᵀv`.
    pub fn decide(&self, m: usize, q: usize, n: usize) -> Route {
        if let Some(force) = self.cfg.force {
            return match force {
                Route::PjrtDense if self.registry.is_none() => Route::NativeGvt,
                other => other,
            };
        }
        let Some(reg) = &self.registry else {
            return Route::NativeGvt;
        };
        if reg.find_bucket("kron_mv", &[("m", m), ("q", q), ("n", n)]).is_none() {
            return Route::NativeGvt;
        }
        let native = complexity::gvt_cost(q, q, m, m, n, n) as f64;
        let dense = complexity::dense_path_cost(q, q, m, m, n, n) as f64 * self.cfg.pjrt_overhead;
        if dense < native {
            Route::PjrtDense
        } else {
            Route::NativeGvt
        }
    }

    /// Routed `u = R(G⊗K)Rᵀ v` (K, G symmetric kernel matrices; `idx` the
    /// `(end, start)` edge index).
    pub fn kron_mv(&self, k: &Matrix, g: &Matrix, idx: &KronIndex, v: &[f64]) -> Vec<f64> {
        let route = self.decide(k.rows(), g.rows(), idx.len());
        match route {
            Route::PjrtDense => {
                let reg = self.registry.as_ref().expect("decide() guarantees registry");
                match reg.kron_mv(k, g, idx, v) {
                    Ok(u) => {
                        self.pjrt_calls.fetch_add(1, Ordering::Relaxed);
                        return u;
                    }
                    Err(err) => {
                        crate::log_warn!("PJRT kron_mv failed ({err}); falling back to native");
                    }
                }
                self.native_mv(k, g, idx, v)
            }
            Route::NativeGvt => self.native_mv(k, g, idx, v),
        }
    }

    fn native_mv(&self, k: &Matrix, g: &Matrix, idx: &KronIndex, v: &[f64]) -> Vec<f64> {
        self.native_calls.fetch_add(1, Ordering::Relaxed);
        let mut u = vec![0.0; idx.len()];
        self.pool.with(|ws| gvt_apply_into(g, k, g, k, idx, idx, v, &mut u, ws, None));
        u
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::solvers::LinOp;
    use crate::util::rng::Pcg32;
    use std::sync::Arc;

    fn toy_kernels(seed: u64, m: usize, q: usize, n: usize) -> (Matrix, Matrix, KronIndex) {
        let mut rng = Pcg32::seeded(seed);
        let kf = Matrix::from_fn(m, 4, |_, _| rng.normal());
        let gf = Matrix::from_fn(q, 4, |_, _| rng.normal());
        let k = crate::kernels::KernelKind::Gaussian { gamma: 0.3 }.square_matrix(&kf);
        let g = crate::kernels::KernelKind::Gaussian { gamma: 0.3 }.square_matrix(&gf);
        let idx = KronIndex::new(
            (0..n).map(|_| rng.below(q) as u32).collect(),
            (0..n).map(|_| rng.below(m) as u32).collect(),
        );
        (k, g, idx)
    }

    #[test]
    fn native_only_routes_native() {
        let router = Router::native_only(RouterConfig::default());
        assert_eq!(router.decide(100, 100, 1000), Route::NativeGvt);
        assert!(!router.has_pjrt());
    }

    #[test]
    fn native_mv_matches_operator() {
        let (k, g, idx) = toy_kernels(1000, 8, 7, 30);
        let mut rng = Pcg32::seeded(1001);
        let v = rng.normal_vec(30);
        let router = Router::native_only(RouterConfig::default());
        let u1 = router.kron_mv(&k, &g, &idx, &v);
        let op = crate::gvt::KronKernelOp::new(Arc::new(g.clone()), Arc::new(k.clone()), idx);
        let u2 = op.apply_vec(&v);
        crate::linalg::vecops::assert_allclose(&u1, &u2, 1e-12, 1e-12);
        assert_eq!(router.stats().native_calls, 1);
    }

    #[test]
    fn forced_pjrt_degrades_to_native_without_registry() {
        let router = Router::native_only(RouterConfig {
            force: Some(Route::PjrtDense),
            ..Default::default()
        });
        assert_eq!(router.decide(10, 10, 50), Route::NativeGvt);
    }
}
