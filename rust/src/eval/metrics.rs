//! Auxiliary metrics: classification accuracy, RMSE, and the regularized
//! risk `J(f)` tracked by the convergence experiments (Figs. 3–5).

/// Classification accuracy with the sign rule (`ŷ = sign(score)`).
pub fn accuracy(labels: &[f64], scores: &[f64]) -> f64 {
    assert_eq!(labels.len(), scores.len());
    if labels.is_empty() {
        return 0.0;
    }
    let correct = labels
        .iter()
        .zip(scores)
        .filter(|(&y, &s)| (s >= 0.0) == (y > 0.0))
        .count();
    correct as f64 / labels.len() as f64
}

/// Root mean squared error.
pub fn rmse(labels: &[f64], scores: &[f64]) -> f64 {
    assert_eq!(labels.len(), scores.len());
    if labels.is_empty() {
        return 0.0;
    }
    let mse = labels
        .iter()
        .zip(scores)
        .map(|(y, s)| (y - s) * (y - s))
        .sum::<f64>()
        / labels.len() as f64;
    mse.sqrt()
}

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Sample standard deviation (0 for < 2 elements).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let mu = mean(xs);
    (xs.iter().map(|x| (x - mu) * (x - mu)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_signs() {
        let y = vec![1.0, -1.0, 1.0, -1.0];
        let s = vec![0.3, -2.0, -0.1, 5.0];
        assert!((accuracy(&y, &s) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rmse_basic() {
        assert!((rmse(&[1.0, 2.0], &[1.0, 4.0]) - 2f64.sqrt()).abs() < 1e-12);
        assert_eq!(rmse(&[], &[]), 0.0);
    }

    #[test]
    fn mean_stddev() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - (32.0f64 / 7.0).sqrt()).abs() < 1e-12);
    }
}
