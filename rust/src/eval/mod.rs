//! Evaluation: AUC (the paper's metric throughout §5), auxiliary metrics,
//! and experiment-result tables.

pub mod auc;
pub mod metrics;

pub use auc::auc;
pub use metrics::{accuracy, rmse};
