//! Area under the ROC curve, computed exactly via the rank statistic with
//! proper tie handling (average ranks). `O(n log n)`.

/// AUC of `scores` against ±1 (or 0/1) `labels`. Returns 0.5 when one class
/// is absent (undefined AUC — the conventional fallback).
///
/// A NaN score has no rank, so any NaN in `scores` makes the statistic
/// undefined and the function returns NaN — a broken model must surface as
/// a broken metric, not silently rank its NaN outputs as ties.
pub fn auc(labels: &[f64], scores: &[f64]) -> f64 {
    assert_eq!(labels.len(), scores.len());
    let n = labels.len();
    if scores.iter().any(|s| s.is_nan()) {
        return f64::NAN;
    }
    let n_pos = labels.iter().filter(|&&y| y > 0.0).count();
    let n_neg = n - n_pos;
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }

    // Sort indices by score (total_cmp: no NaN left by the guard above, and
    // the comparator stays a total order regardless).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| scores[i].total_cmp(&scores[j]));

    let mut rank_sum_pos = 0.0;
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[order[j + 1]] == scores[order[i]] {
            j += 1;
        }
        // ranks i+1 ..= j+1 (1-based) share the average rank
        let avg_rank = (i + 1 + j + 1) as f64 / 2.0;
        for &idx in &order[i..=j] {
            if labels[idx] > 0.0 {
                rank_sum_pos += avg_rank;
            }
        }
        i = j + 1;
    }

    (rank_sum_pos - (n_pos * (n_pos + 1)) as f64 / 2.0) / (n_pos as f64 * n_neg as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Brute-force O(n²) AUC with ½-credit for ties.
    fn auc_brute(labels: &[f64], scores: &[f64]) -> f64 {
        let mut wins = 0.0;
        let mut pairs = 0.0;
        for i in 0..labels.len() {
            if labels[i] <= 0.0 {
                continue;
            }
            for j in 0..labels.len() {
                if labels[j] > 0.0 {
                    continue;
                }
                pairs += 1.0;
                if scores[i] > scores[j] {
                    wins += 1.0;
                } else if scores[i] == scores[j] {
                    wins += 0.5;
                }
            }
        }
        wins / pairs
    }

    #[test]
    fn perfect_and_inverted() {
        let labels = vec![1.0, 1.0, -1.0, -1.0];
        assert_eq!(auc(&labels, &[4.0, 3.0, 2.0, 1.0]), 1.0);
        assert_eq!(auc(&labels, &[1.0, 2.0, 3.0, 4.0]), 0.0);
    }

    #[test]
    fn constant_scores_give_half() {
        let labels = vec![1.0, -1.0, 1.0, -1.0];
        assert_eq!(auc(&labels, &[0.5; 4]), 0.5);
    }

    #[test]
    fn single_class_returns_half() {
        assert_eq!(auc(&[1.0, 1.0], &[0.1, 0.9]), 0.5);
        assert_eq!(auc(&[-1.0, -1.0], &[0.1, 0.9]), 0.5);
    }

    #[test]
    fn nan_scores_surface_as_nan() {
        // regression: NaN used to be treated as a tie with everything,
        // silently corrupting the ranking
        let labels = vec![1.0, -1.0, 1.0, -1.0];
        assert!(auc(&labels, &[0.9, 0.1, f64::NAN, 0.4]).is_nan());
        assert!(auc(&labels, &[f64::NAN; 4]).is_nan());
        // infinities are legitimate scores with a well-defined rank
        assert_eq!(auc(&labels, &[f64::INFINITY, 0.1, 0.9, f64::NEG_INFINITY]), 1.0);
    }

    #[test]
    fn matches_brute_force_with_ties() {
        let mut rng = Pcg32::seeded(200);
        for _ in 0..20 {
            let n = 3 + rng.below(40);
            let labels: Vec<f64> =
                (0..n).map(|_| if rng.bernoulli(0.4) { 1.0 } else { -1.0 }).collect();
            // quantized scores to force ties
            let scores: Vec<f64> = (0..n).map(|_| (rng.uniform() * 8.0).round() / 8.0).collect();
            if labels.iter().all(|&y| y > 0.0) || labels.iter().all(|&y| y <= 0.0) {
                continue;
            }
            let fast = auc(&labels, &scores);
            let slow = auc_brute(&labels, &scores);
            assert!((fast - slow).abs() < 1e-12, "{fast} vs {slow}");
        }
    }

    #[test]
    fn invariant_to_monotone_transform() {
        let mut rng = Pcg32::seeded(201);
        let n = 50;
        let labels: Vec<f64> =
            (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect();
        let scores = rng.normal_vec(n);
        let transformed: Vec<f64> = scores.iter().map(|s| (s * 0.5).exp()).collect();
        assert!((auc(&labels, &scores) - auc(&labels, &transformed)).abs() < 1e-12);
    }
}
