//! Primal Kronecker predictor and the matrix-free primal operators of
//! Algorithm 3 (linear vertex kernels, explicit feature maps).
//!
//! The primal weight vector `w ∈ R^{d·r}` uses the flat layout
//! `w[jT·d + jD]` — `left` factor = end-vertex feature `jT`, `right` =
//! start-vertex feature `jD` — consistent with
//! [`KronIndex::flat`](crate::gvt::KronIndex::flat) and the `T ⊗ D` pair
//! ordering. Equivalently `w = vec(W)` with `W ∈ R^{r×d}`, and
//! `f(d,t) = tᵀ W d`.

use crate::data::Dataset;
use crate::gvt::dense::{gather_edges, scatter_edges};
use crate::linalg::solvers::LinOp;
use crate::linalg::Matrix;

/// A trained primal model (linear vertex kernels only).
#[derive(Debug, Clone)]
pub struct PrimalModel {
    /// Flat weights, length `d·r`, layout `w[jT·d + jD]`.
    pub w: Vec<f64>,
    /// Start-vertex feature dimension `d`.
    pub d_features: usize,
    /// End-vertex feature dimension `r`.
    pub r_features: usize,
}

impl PrimalModel {
    /// View `w` as the `r×d` interaction matrix `W` (`f(d,t) = tᵀ W d`).
    pub fn weight_matrix(&self) -> Matrix {
        Matrix::from_vec(self.r_features, self.d_features, self.w.clone())
    }

    /// Predict scores for all edges of `test`:
    /// `s_h = t_{end_h}ᵀ W d_{start_h}`, computed as one GEMM
    /// (`Z = T̂·W`, `u×d`) plus a dot per edge — `O(v·r·d + t·d)`.
    pub fn predict(&self, test: &Dataset) -> Vec<f64> {
        assert_eq!(test.start_features.cols(), self.d_features, "start feature dim");
        assert_eq!(test.end_features.cols(), self.r_features, "end feature dim");
        let w = self.weight_matrix();
        let z = test.end_features.matmul(&w); // v×d
        (0..test.n_edges())
            .map(|h| {
                crate::linalg::vecops::dot(
                    z.row(test.end_idx[h] as usize),
                    test.start_features.row(test.start_idx[h] as usize),
                )
            })
            .collect()
    }
}

/// Matrix-free primal edge-design operator `X = R(T ⊗ D) ∈ R^{n×(d·r)}`
/// (Algorithm 3), exposing `X w`, `Xᵀ g`, and the Newton-system operator
/// `Xᵀ H X + λI`.
///
/// Forward and adjoint use the dense Roth-lemma path:
/// `X w = gather(D W Tᵀ)` and `Xᵀ g = vec(Dᵀ V_g T)` with `V_g` the edge
/// scatter — `O(m·d·q + d·q·r + n)`, matching the paper's primal complexity
/// class `O(min(q·d·r + d·n, m·d·r + r·n))` without materializing `X`.
pub struct PrimalKronOp {
    /// Start-vertex features `D` (`m×d`).
    d: Matrix,
    /// End-vertex features `T` (`q×r`).
    t: Matrix,
    start_idx: Vec<u32>,
    end_idx: Vec<u32>,
}

impl PrimalKronOp {
    /// Operator over a dataset's features and edges (copies both).
    pub fn new(dataset: &Dataset) -> PrimalKronOp {
        PrimalKronOp {
            d: dataset.start_features.clone(),
            t: dataset.end_features.clone(),
            start_idx: dataset.start_idx.clone(),
            end_idx: dataset.end_idx.clone(),
        }
    }

    /// Number of training edges `n`.
    pub fn n_edges(&self) -> usize {
        self.start_idx.len()
    }

    /// Weight dimension `d·r`.
    pub fn w_dim(&self) -> usize {
        self.d.cols() * self.t.cols()
    }

    /// `p = X w` — predictions on the training edges.
    pub fn forward(&self, w: &[f64]) -> Vec<f64> {
        assert_eq!(w.len(), self.w_dim());
        let w_mat = Matrix::from_vec(self.t.cols(), self.d.cols(), w.to_vec()); // r×d
        // P = D Wᵀ? We need p_h = t_hᵀ W d_h: Z = T W (q×d); p_h = Z[end_h]·D[start_h]
        let z = self.t.matmul(&w_mat); // q×d
        (0..self.n_edges())
            .map(|h| {
                crate::linalg::vecops::dot(
                    z.row(self.end_idx[h] as usize),
                    self.d.row(self.start_idx[h] as usize),
                )
            })
            .collect()
    }

    /// `z = Xᵀ g` — scatter edge values, then two GEMMs.
    pub fn adjoint(&self, g: &[f64]) -> Vec<f64> {
        assert_eq!(g.len(), self.n_edges());
        // V_g[i,j] = Σ_{h: start=i, end=j} g_h   (m×q)
        let v_g = scatter_edges(g, &self.start_idx, &self.end_idx, self.d.rows(), self.t.rows());
        // Z = Tᵀ V_gᵀ D = (V_g T)ᵀ? We need z[jT·d + jD] = Σ_{i,j} T[j,jT]·D[i,jD]·V_g[i,j]
        // = (Tᵀ V_gᵀ D)[jT, jD]
        let vt = v_g.transpose(); // q×m
        let z = self.t.transpose().matmul(&vt).matmul(&self.d); // r×m · m? -> r×q? careful:
        debug_assert_eq!(z.rows(), self.t.cols());
        debug_assert_eq!(z.cols(), self.d.cols());
        z.into_vec()
    }

    /// Gather helper for masked forward products.
    pub fn gather(&self, p: &Matrix) -> Vec<f64> {
        gather_edges(p, &self.start_idx, &self.end_idx)
    }
}

/// The primal Newton-system operator `Xᵀ·diag(h)·X + λI` (line 5 of
/// Algorithm 3) — symmetric PSD, solvable by CG/MINRES.
pub struct PrimalNewtonOp<'a> {
    /// The primal design operator `X`.
    pub op: &'a PrimalKronOp,
    /// Diagonal of the loss Hessian at the current point (`h ∈ {0,1}ⁿ` for
    /// L2-SVM, all-ones for ridge).
    pub hess_diag: Vec<f64>,
    /// Regularization parameter λ.
    pub lambda: f64,
}

impl LinOp for PrimalNewtonOp<'_> {
    fn dim(&self) -> usize {
        self.op.w_dim()
    }

    fn apply(&self, x: &[f64], y: &mut [f64]) {
        let mut p = self.op.forward(x);
        for (pi, hi) in p.iter_mut().zip(&self.hess_diag) {
            *pi *= hi;
        }
        let z = self.op.adjoint(&p);
        for i in 0..x.len() {
            y[i] = z[i] + self.lambda * x[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gvt::explicit::explicit_submatrix;
    use crate::gvt::KronIndex;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    fn toy_dataset(seed: u64) -> Dataset {
        let mut rng = Pcg32::seeded(seed);
        let (m, q, n) = (5, 4, 11);
        Dataset {
            start_features: Matrix::from_fn(m, 3, |_, _| rng.normal()),
            end_features: Matrix::from_fn(q, 2, |_, _| rng.normal()),
            start_idx: (0..n).map(|_| rng.below(m) as u32).collect(),
            end_idx: (0..n).map(|_| rng.below(q) as u32).collect(),
            labels: vec![0.0; n],
            name: "toy".into(),
        }
    }

    /// Materialized X = R(T⊗D) for testing: row h, col (jT·d + jD).
    fn explicit_design(ds: &Dataset) -> Matrix {
        let full_cols = KronIndex::new(
            (0..ds.end_features.cols() * ds.start_features.cols())
                .map(|l| (l / ds.start_features.cols()) as u32)
                .collect(),
            (0..ds.end_features.cols() * ds.start_features.cols())
                .map(|l| (l % ds.start_features.cols()) as u32)
                .collect(),
        );
        explicit_submatrix(&ds.end_features, &ds.start_features, &ds.kron_index(), &full_cols)
    }

    #[test]
    fn forward_matches_explicit_design() {
        let ds = toy_dataset(310);
        let op = PrimalKronOp::new(&ds);
        let mut rng = Pcg32::seeded(311);
        let w = rng.normal_vec(op.w_dim());
        let fast = op.forward(&w);
        let x = explicit_design(&ds);
        let slow = x.matvec(&w);
        assert_allclose(&fast, &slow, 1e-10, 1e-10);
    }

    #[test]
    fn adjoint_matches_explicit_design() {
        let ds = toy_dataset(312);
        let op = PrimalKronOp::new(&ds);
        let mut rng = Pcg32::seeded(313);
        let g = rng.normal_vec(op.n_edges());
        let fast = op.adjoint(&g);
        let x = explicit_design(&ds);
        let slow = x.matvec_t(&g);
        assert_allclose(&fast, &slow, 1e-10, 1e-10);
    }

    #[test]
    fn adjoint_is_true_adjoint() {
        let ds = toy_dataset(314);
        let op = PrimalKronOp::new(&ds);
        let mut rng = Pcg32::seeded(315);
        let w = rng.normal_vec(op.w_dim());
        let g = rng.normal_vec(op.n_edges());
        let lhs = crate::linalg::vecops::dot(&op.forward(&w), &g);
        let rhs = crate::linalg::vecops::dot(&w, &op.adjoint(&g));
        assert!((lhs - rhs).abs() < 1e-9, "{lhs} vs {rhs}");
    }

    #[test]
    fn newton_op_is_symmetric_psd() {
        let ds = toy_dataset(316);
        let op = PrimalKronOp::new(&ds);
        let mut rng = Pcg32::seeded(317);
        let hess: Vec<f64> =
            (0..op.n_edges()).map(|i| if i % 3 == 0 { 0.0 } else { 1.0 }).collect();
        let newton = PrimalNewtonOp { op: &op, hess_diag: hess, lambda: 0.1 };
        let x = rng.normal_vec(newton.dim());
        let y = rng.normal_vec(newton.dim());
        let ax = newton.apply_vec(&x);
        let ay = newton.apply_vec(&y);
        let lhs = crate::linalg::vecops::dot(&ax, &y);
        let rhs = crate::linalg::vecops::dot(&x, &ay);
        assert!((lhs - rhs).abs() < 1e-9);
        assert!(crate::linalg::vecops::dot(&ax, &x) > 0.0);
    }

    #[test]
    fn primal_model_predicts_via_weight_matrix() {
        let ds = toy_dataset(318);
        let mut rng = Pcg32::seeded(319);
        let model = PrimalModel { w: rng.normal_vec(6), d_features: 3, r_features: 2 };
        let preds = model.predict(&ds);
        let w = model.weight_matrix();
        for h in 0..ds.n_edges() {
            let d = ds.start_features.row(ds.start_idx[h] as usize);
            let t = ds.end_features.row(ds.end_idx[h] as usize);
            let mut expect = 0.0;
            for jt in 0..2 {
                for jd in 0..3 {
                    expect += t[jt] * w.get(jt, jd) * d[jd];
                }
            }
            assert!((preds[h] - expect).abs() < 1e-10);
        }
    }
}
