//! [`TensorModel`] — the trained dual model of a **D-way tensor-product
//! chain**: dual coefficients over the training cells plus the per-mode
//! training features and kernels needed to score new cells through
//! [`TensorPredictOp`].
//!
//! The D-way analogue of [`DualModel`](super::DualModel): prediction builds
//! one rectangular test–train kernel block **per mode** and pushes the dual
//! vector through the chained GVT apply — the `(K̂₁⊗…⊗K̂_D)` product is
//! never materialized.

use crate::data::TensorDataset;
use crate::gvt::{TensorIndex, TensorPredictOp};
use crate::kernels::{kernel_matrix_threaded, KernelKind};
use crate::linalg::Matrix;

/// A trained D-way tensor-chain dual model.
///
/// Produced by [`TensorRidge`](crate::train::TensorRidge) (or directly);
/// scores a [`TensorDataset`] of test cells on the same per-mode vertex
/// domains via [`TensorModel::predict`].
#[derive(Debug, Clone)]
pub struct TensorModel {
    /// Dual coefficients, one per training cell.
    pub dual_coef: Vec<f64>,
    /// Per-mode training vertex features; `train_features[d]` has one row
    /// per mode-`d` vertex.
    pub train_features: Vec<Matrix>,
    /// Per-mode vertex columns of the training cells.
    pub train_idx: TensorIndex,
    /// One kernel per mode, applied to that mode's features.
    pub kernels: Vec<KernelKind>,
}

impl TensorModel {
    /// Number of modes `D` in the chain.
    pub fn order(&self) -> usize {
        self.train_features.len()
    }

    /// Number of training cells (length of the dual vector).
    pub fn n_train(&self) -> usize {
        self.dual_coef.len()
    }

    /// Number of nonzero dual coefficients (drives the sparse prediction
    /// shortcut of eq. 5).
    pub fn nnz(&self) -> usize {
        self.dual_coef.iter().filter(|&&a| a != 0.0).count()
    }

    /// Per-mode training vertex counts.
    pub fn mode_dims(&self) -> Vec<usize> {
        self.train_features.iter().map(|f| f.rows()).collect()
    }

    /// Structural validation: mode counts agree across features / index /
    /// kernels, the dual vector covers every indexed cell, indices in
    /// bounds.
    pub fn validate(&self) -> Result<(), String> {
        if self.train_features.len() < 2 {
            return Err(format!(
                "tensor model needs at least two modes, got {}",
                self.train_features.len()
            ));
        }
        if self.train_features.len() != self.train_idx.order() {
            return Err(format!(
                "{} feature matrices but the training index has {} modes",
                self.train_features.len(),
                self.train_idx.order()
            ));
        }
        if self.kernels.len() != self.train_features.len() {
            return Err(format!(
                "{} mode kernels but {} modes",
                self.kernels.len(),
                self.train_features.len()
            ));
        }
        if self.dual_coef.len() != self.train_idx.len() {
            return Err(format!(
                "dual vector has {} entries but the model was trained on {} cells",
                self.dual_coef.len(),
                self.train_idx.len()
            ));
        }
        self.train_idx.validate(&self.mode_dims())
    }

    /// Check that `test` lives on compatible per-mode feature domains.
    fn check_test(&self, test: &TensorDataset) -> Result<(), String> {
        if test.order() != self.order() {
            return Err(format!(
                "test data has {} modes but the model was trained on {}",
                test.order(),
                self.order()
            ));
        }
        for (d, (te, tr)) in test.features.iter().zip(&self.train_features).enumerate() {
            if te.cols() != tr.cols() {
                return Err(format!(
                    "mode {d} test features have {} columns but training used {}",
                    te.cols(),
                    tr.cols()
                ));
            }
        }
        test.index.validate(&test.dims()).map_err(|e| format!("test index: {e}"))
    }

    /// Build the rectangular prediction operator for the cells of `test`:
    /// one `t_d × m_d` test–train kernel block per mode, composed into a
    /// [`TensorPredictOp`] sharded over `threads`.
    pub fn predict_op(
        &self,
        test: &TensorDataset,
        threads: usize,
    ) -> Result<TensorPredictOp, String> {
        self.check_test(test)?;
        let blocks: Vec<Matrix> = self
            .kernels
            .iter()
            .zip(&test.features)
            .zip(&self.train_features)
            .map(|((&k, te), tr)| kernel_matrix_threaded(k, te, tr, threads))
            .collect();
        Ok(TensorPredictOp::new(blocks, test.index.clone(), self.train_idx.clone())
            .with_threads(threads))
    }

    /// Predict scores for every cell of `test` (serial).
    pub fn predict(&self, test: &TensorDataset) -> Result<Vec<f64>, String> {
        self.predict_threaded(test, 1)
    }

    /// [`TensorModel::predict`] with the kernel-block builds and the chained
    /// GVT matvec sharded over `threads` (bitwise identical to serial).
    pub fn predict_threaded(
        &self,
        test: &TensorDataset,
        threads: usize,
    ) -> Result<Vec<f64>, String> {
        Ok(self.predict_op(test, threads)?.predict(&self.dual_coef))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::GridCheckerboardConfig;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    fn toy_model_and_data(seed: u64) -> (TensorModel, TensorDataset, TensorDataset) {
        let ds = GridCheckerboardConfig {
            dims: vec![5, 4, 6],
            density: 0.5,
            noise: 0.1,
            feature_range: 4.0,
            seed,
        }
        .generate();
        let (train, test) = ds.holdout_split(0.3, seed ^ 1);
        let mut rng = Pcg32::seeded(seed ^ 2);
        let model = TensorModel {
            dual_coef: rng.normal_vec(train.n_edges()),
            train_features: train.features.clone(),
            train_idx: train.index.clone(),
            kernels: vec![
                KernelKind::Gaussian { gamma: 0.5 },
                KernelKind::Linear,
                KernelKind::Gaussian { gamma: 0.25 },
            ],
        };
        model.validate().unwrap();
        (model, train, test)
    }

    /// Brute-force oracle: score_h = Σ_l a_l · Π_d K̂_d[i^d_h, j^d_l].
    fn oracle(model: &TensorModel, test: &TensorDataset) -> Vec<f64> {
        let blocks: Vec<Matrix> = model
            .kernels
            .iter()
            .zip(&test.features)
            .zip(&model.train_features)
            .map(|((&k, te), tr)| kernel_matrix_threaded(k, te, tr, 1))
            .collect();
        (0..test.n_edges())
            .map(|h| {
                (0..model.n_train())
                    .map(|l| {
                        model.dual_coef[l]
                            * blocks
                                .iter()
                                .enumerate()
                                .map(|(d, b)| {
                                    b.get(
                                        test.index.modes[d][h] as usize,
                                        model.train_idx.modes[d][l] as usize,
                                    )
                                })
                                .product::<f64>()
                    })
                    .sum()
            })
            .collect()
    }

    #[test]
    fn predict_matches_brute_force_oracle() {
        let (model, _train, test) = toy_model_and_data(21);
        let want = oracle(&model, &test);
        let got = model.predict(&test).unwrap();
        assert_allclose(&got, &want, 1e-10, 1e-10);
        // threaded predictions are bitwise identical to serial
        for threads in [2, 4] {
            assert_eq!(model.predict_threaded(&test, threads).unwrap(), got);
        }
    }

    #[test]
    fn predict_rejects_incompatible_test_data() {
        let (model, train, test) = toy_model_and_data(22);
        // wrong mode count
        let mut two_mode = test.clone();
        two_mode.features.truncate(2);
        two_mode.index = TensorIndex::new(two_mode.index.modes[..2].to_vec());
        assert!(model.predict(&two_mode).is_err());
        // wrong feature width on one mode
        let mut wide = test.clone();
        wide.features[1] = Matrix::zeros(wide.features[1].rows(), 3);
        assert!(model.predict(&wide).is_err());
        // malformed model
        let mut short = model.clone();
        short.dual_coef.pop();
        assert!(short.validate().is_err());
        drop(train);
    }
}
