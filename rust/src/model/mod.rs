//! Trained predictors.
//!
//! * [`dual`] — the dual model `f(d,t) = Σᵢ aᵢ k(d_{rᵢ},d) g(t_{sᵢ},t)`
//!   with the efficient zero-shot prediction of §3.1 plus an explicit
//!   ("Baseline") prediction path for the Fig. 6 comparison.
//! * [`primal`] — the primal model `f(d,t) = ⟨d ⊗ t, w⟩` for linear vertex
//!   kernels, and the matrix-free primal operators of Algorithm 3.
//! * [`tensor`] — the D-way tensor-chain dual model
//!   `f(x¹,…,x^D) = Σᵢ aᵢ Π_d k_d(x^d_{iᵈ}, x^d)`, the generalization of
//!   the dual model to tensor-product grids.

pub mod dual;
pub mod primal;
pub mod tensor;

pub use dual::{predict_path, DualModel, PredictContext};
pub use primal::{PrimalKronOp, PrimalModel};
pub use tensor::TensorModel;
