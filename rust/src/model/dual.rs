//! Dual Kronecker kernel predictor (§3.1).
//!
//! `f(d,t) = Σᵢ aᵢ · k(d_{rᵢ}, d) · g(t_{sᵢ}, t)` over the training edges.
//! Prediction for a batch of test edges is `R̂(Ĝ⊗K̂)Rᵀa`, computed with the
//! generalized vec trick in `O(min(v‖a‖₀ + m·t, u‖a‖₀ + q·t))` (eq. 5)
//! versus `O(t·‖a‖₀)` for the explicit decision function (eq. 6) — the
//! comparison of Fig. 6 (middle).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::api::Compute;
use crate::data::Dataset;
use crate::gvt::{delta_matrix, KronIndex, PairwiseKernelKind, PairwiseOp, PairwiseShared};
use crate::kernels::{
    kernel_matrix_threaded, kernel_row_into, kernel_value, row_sq_norms, KernelKind,
    KernelRowCache,
};
use crate::linalg::Matrix;

/// A trained dual model. Stores the training vertex features (to evaluate
/// test–train kernel blocks), the edge index, the pairwise kernel family,
/// and the dual coefficients.
#[derive(Debug, Clone)]
pub struct DualModel {
    /// Dual coefficients `a ∈ Rⁿ` (sparse for SVM: many exact zeros).
    pub dual_coef: Vec<f64>,
    /// Training start-vertex features (`m × d`).
    pub train_start_features: Matrix,
    /// Training end-vertex features (`q × r`).
    pub train_end_features: Matrix,
    /// Training edge index: `left` = end-vertex, `right` = start-vertex.
    pub train_idx: KronIndex,
    /// Start-vertex kernel `k`.
    pub kernel_d: KernelKind,
    /// End-vertex kernel `g`.
    pub kernel_t: KernelKind,
    /// Pairwise kernel family the model was trained with (`Kronecker`
    /// reproduces the pre-family scoring bit for bit).
    pub pairwise: PairwiseKernelKind,
}

impl DualModel {
    /// Number of non-zero dual coefficients (`‖a‖₀`; SVM support size).
    pub fn nnz(&self) -> usize {
        self.dual_coef.iter().filter(|&&a| a != 0.0).count()
    }

    /// Drop explicit zeros from the model: prunes coefficients and the edge
    /// index so prediction cost scales with `‖a‖₀` (the sparse shortcut the
    /// paper applies to SVM predictors).
    pub fn pruned(&self) -> DualModel {
        let keep: Vec<usize> =
            (0..self.dual_coef.len()).filter(|&i| self.dual_coef[i] != 0.0).collect();
        DualModel {
            dual_coef: keep.iter().map(|&i| self.dual_coef[i]).collect(),
            train_start_features: self.train_start_features.clone(),
            train_end_features: self.train_end_features.clone(),
            train_idx: KronIndex::new(
                keep.iter().map(|&i| self.train_idx.left[i]).collect(),
                keep.iter().map(|&i| self.train_idx.right[i]).collect(),
            ),
            kernel_d: self.kernel_d,
            kernel_t: self.kernel_t,
            pairwise: self.pairwise,
        }
    }

    /// Build the pairwise prediction operator for a batch of test edges.
    /// Useful when predicting repeatedly for the same test vertices
    /// (serving). For `Kronecker` models the operator is bitwise identical
    /// to the legacy `KronPredictOp` path.
    ///
    /// Panics if the model's pairwise configuration is invalid (trainers
    /// validate it at fit time, so trained models are always valid).
    pub fn predict_op(&self, test: &Dataset) -> PairwiseOp {
        PairwiseOp::prediction_from_features(
            self.pairwise,
            self.kernel_d,
            self.kernel_t,
            &test.start_features,
            &test.end_features,
            &self.train_start_features,
            &self.train_end_features,
            test.kron_index(),
            self.train_idx.clone(),
            1,
        )
        .expect("trained model carries a valid pairwise configuration")
    }

    /// Build a long-lived serving context around this model: prunes zero
    /// coefficients once, prebuilds the train-side
    /// [`EdgePlan`](crate::gvt::EdgePlan)s (via [`PairwiseShared`], including
    /// the swapped-column plan of the symmetric family), precomputes the
    /// per-vertex squared norms the kernel rows need, and (when
    /// `cache_vertices > 0`) attaches a per-side LRU kernel-row cache. Every
    /// incoming test batch then pays only for its own test-side work — see
    /// [`PredictContext`].
    ///
    /// The [`Compute`] policy supplies every execution knob:
    /// `compute.threads` shards each batch's GVT matvec (`0` = all cores,
    /// `1` = serial), `compute.cache_vertices` bounds each side's kernel-row
    /// cache, and `compute.workspace_retention` bounds the pooled scratch
    /// workspaces. All three are transparent to results.
    pub fn predict_context(&self, compute: &Compute) -> PredictContext {
        let (threads, cache_vertices) = (compute.threads, compute.cache_vertices);
        let pruned = self.pruned();
        let q_train = pruned.train_end_features.rows();
        let m_train = pruned.train_start_features.rows();
        let shared = PairwiseShared::with_pool_retention(
            self.pairwise,
            Arc::new(pruned.train_idx),
            q_train,
            m_train,
            compute.workspace_retention,
        );
        let hits = Arc::new(AtomicUsize::new(0));
        let misses = Arc::new(AtomicUsize::new(0));
        PredictContext {
            start_sq: row_sq_norms(&pruned.train_start_features),
            end_sq: row_sq_norms(&pruned.train_end_features),
            dual_coef: pruned.dual_coef,
            train_start_features: pruned.train_start_features,
            train_end_features: pruned.train_end_features,
            kernel_d: pruned.kernel_d,
            kernel_t: pruned.kernel_t,
            pairwise: self.pairwise,
            shared,
            threads,
            cache_vertices,
            start_cache: make_cache(cache_vertices, &hits, &misses),
            end_cache: make_cache(cache_vertices, &hits, &misses),
            hits,
            misses,
        }
    }

    /// Predict scores for all edges of `test` via the generalized vec trick.
    pub fn predict(&self, test: &Dataset) -> Vec<f64> {
        self.predict_op(test).predict(&self.dual_coef)
    }

    /// [`DualModel::predict`] with both the kernel-block builds and the GVT
    /// matvec sharded over `threads` worker threads (`0` = all cores, `1` =
    /// serial). Scores are bitwise identical to the serial path for every
    /// thread count (the threaded GEMM and the GVT engine are both bitwise
    /// deterministic).
    pub fn predict_threaded(&self, test: &Dataset, threads: usize) -> Vec<f64> {
        PairwiseOp::prediction_from_features(
            self.pairwise,
            self.kernel_d,
            self.kernel_t,
            &test.start_features,
            &test.end_features,
            &self.train_start_features,
            &self.train_end_features,
            test.kron_index(),
            self.train_idx.clone(),
            threads,
        )
        .expect("trained model carries a valid pairwise configuration")
        .predict(&self.dual_coef)
    }

    /// Explicit ("Baseline") decision function: evaluates the pairwise edge
    /// kernel between every test edge and every support vector, `O(t·‖a‖₀)`
    /// kernel evaluations — the decision function a standard kernel-SVM
    /// package uses. Kept for the Fig. 6 prediction-time comparison and as a
    /// correctness oracle for every [`PairwiseKernelKind`].
    pub fn predict_explicit(&self, test: &Dataset) -> Vec<f64> {
        let mut out = vec![0.0; test.n_edges()];
        let sv: Vec<usize> =
            (0..self.dual_coef.len()).filter(|&i| self.dual_coef[i] != 0.0).collect();
        for h in 0..test.n_edges() {
            let d_feat = test.start_features.row(test.start_idx[h] as usize);
            let t_feat = test.end_features.row(test.end_idx[h] as usize);
            let mut acc = 0.0;
            for &i in &sv {
                acc += self.dual_coef[i] * self.pairwise_kernel_value(d_feat, t_feat, i);
            }
            out[h] = acc;
        }
        out
    }

    /// One explicit pairwise edge-kernel evaluation between the test edge
    /// `(d_feat, t_feat)` and training edge `i` — the scalar formula each
    /// [`PairwiseOp`] term set computes through the GVT.
    fn pairwise_kernel_value(&self, d_feat: &[f64], t_feat: &[f64], i: usize) -> f64 {
        let si = self.train_idx.left[i] as usize; // end vertex
        let ri = self.train_idx.right[i] as usize; // start vertex
        let d_train = self.train_start_features.row(ri);
        let t_train = self.train_end_features.row(si);
        match self.pairwise {
            PairwiseKernelKind::Kronecker => {
                kernel_value(self.kernel_d, d_train, d_feat)
                    * kernel_value(self.kernel_t, t_train, t_feat)
            }
            PairwiseKernelKind::SymmetricKron | PairwiseKernelKind::AntiSymmetricKron => {
                let straight = kernel_value(self.kernel_d, d_train, d_feat)
                    * kernel_value(self.kernel_t, t_train, t_feat);
                let swapped = kernel_value(self.kernel_d, d_train, t_feat)
                    * kernel_value(self.kernel_t, t_train, d_feat);
                if self.pairwise == PairwiseKernelKind::AntiSymmetricKron {
                    0.5 * (straight - swapped)
                } else {
                    0.5 * (straight + swapped)
                }
            }
            PairwiseKernelKind::Cartesian => {
                let mut acc = 0.0;
                if t_train == t_feat {
                    acc += kernel_value(self.kernel_d, d_train, d_feat);
                }
                if d_train == d_feat {
                    acc += kernel_value(self.kernel_t, t_train, t_feat);
                }
                acc
            }
        }
    }
}

/// Score several trained models that share one training side (the output of
/// [`crate::train::KronRidge::fit_path`], or any multi-output family) against
/// one test batch in a **single batched sweep**: the test–train kernel
/// blocks are computed once and one multi-RHS GVT apply scores every model's
/// coefficients together. Returns one score vector per model; entry `j` is
/// **bitwise identical** to `models[j].predict(test)`.
///
/// Errors if `models` is empty or the models do not share their training
/// edge index, features, and kernels (they must come from one training run).
pub fn predict_path(models: &[DualModel], test: &Dataset) -> Result<Vec<Vec<f64>>, String> {
    let first = models.first().ok_or("predict_path needs at least one model")?;
    for (j, model) in models.iter().enumerate().skip(1) {
        if model.train_idx != first.train_idx
            || model.train_start_features != first.train_start_features
            || model.train_end_features != first.train_end_features
            || model.kernel_d != first.kernel_d
            || model.kernel_t != first.kernel_t
            || model.pairwise != first.pairwise
        {
            return Err(format!(
                "model {j} does not share the first model's training side; \
                 predict_path requires models from one training run"
            ));
        }
    }
    let op = first.predict_op(test);
    let n = op.n_train();
    let t = op.n_test();
    let k = models.len();
    if t == 0 {
        return Ok(vec![Vec::new(); k]);
    }
    let mut duals = vec![0.0; n * k];
    for (dj, model) in duals.chunks_mut(n).zip(models) {
        dj.copy_from_slice(&model.dual_coef);
    }
    let scores = op.predict_multi(&duals, k);
    Ok(scores.chunks(t).map(|c| c.to_vec()).collect())
}

fn make_cache(
    capacity: usize,
    hits: &Arc<AtomicUsize>,
    misses: &Arc<AtomicUsize>,
) -> Option<KernelRowCache> {
    (capacity > 0).then(|| KernelRowCache::with_counters(capacity, hits.clone(), misses.clone()))
}

/// Long-lived, cache-aware serving state for a trained [`DualModel`].
///
/// [`DualModel::predict_op`] rebuilds the full test–train kernel blocks and
/// fresh [`EdgePlan`](crate::gvt::EdgePlan)s for every batch; this context
/// hoists everything that depends only on the *trained* side out of the
/// per-batch path:
///
/// * **pruned coefficients + edge index** — zero duals are dropped once, so
///   every batch pays `O(‖a‖₀)` instead of `O(n)` in stage 1 (eq. 5);
/// * **prebuilt [`EdgePlan`](crate::gvt::EdgePlan)s** ([`PairwiseShared`]) —
///   the stage-1 bucketing of the train edges (and, for the symmetric
///   family, of their swapped orientation), shared by every batch operator;
/// * **pooled workspaces** — scratch buffers recycled across batches (and
///   across concurrent callers: the context is `Sync`);
/// * **per-vertex kernel-row LRU caches** — a test vertex seen before (by
///   feature content) reuses its `K̂`/`Ĝ` row instead of recomputing it.
///
/// Cached, sharded, and cold-path results are all **bitwise identical** for
/// a given batch: cached rows are produced by
/// [`kernel_row_into`], which matches
/// [`kernel_matrix`](crate::kernels::kernel_matrix) rows exactly, and the
/// GVT engine is bitwise deterministic across thread counts. (Relative to
/// [`DualModel::predict`], pruning can flip the Algorithm-1 branch choice
/// when the model holds explicit zeros, which changes accumulation order at
/// the ~1e-16 level; models without zero duals match `predict` bitwise.)
pub struct PredictContext {
    dual_coef: Vec<f64>,
    train_start_features: Matrix,
    train_end_features: Matrix,
    kernel_d: KernelKind,
    kernel_t: KernelKind,
    /// Pairwise kernel family of the served model.
    pairwise: PairwiseKernelKind,
    /// Pruned training edge index, its prebuilt stage-1 plans (including
    /// the swapped-column plan of the symmetric family), and the pooled
    /// workspaces — shared (not copied) into every batch operator.
    shared: PairwiseShared,
    /// Squared row norms of the train features (Gaussian/Tanimoto rows).
    start_sq: Vec<f64>,
    end_sq: Vec<f64>,
    threads: usize,
    cache_vertices: usize,
    start_cache: Option<KernelRowCache>,
    end_cache: Option<KernelRowCache>,
    hits: Arc<AtomicUsize>,
    misses: Arc<AtomicUsize>,
}

impl PredictContext {
    /// Rebind the cache hit/miss counters to externally owned atomics (the
    /// prediction server passes its `ServerStats` fields). Resets the caches;
    /// call right after [`DualModel::predict_context`].
    pub fn with_cache_counters(
        mut self,
        hits: Arc<AtomicUsize>,
        misses: Arc<AtomicUsize>,
    ) -> Self {
        self.start_cache = make_cache(self.cache_vertices, &hits, &misses);
        self.end_cache = make_cache(self.cache_vertices, &hits, &misses);
        self.hits = hits;
        self.misses = misses;
        self
    }

    /// Number of non-zero dual coefficients retained (`‖a‖₀`).
    pub fn nnz(&self) -> usize {
        self.dual_coef.len()
    }

    /// Trained-side feature dimensions `(d, r)` — what request vertex rows
    /// must match. The prediction server validates against these and
    /// requires them to be stable across [hot
    /// swaps](crate::coordinator::PredictServer::swap_model).
    pub fn feature_dims(&self) -> (usize, usize) {
        (self.train_start_features.cols(), self.train_end_features.cols())
    }

    /// Worker threads used per batch matvec.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Kernel-row cache hits so far (both sides combined).
    pub fn cache_hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Kernel-row cache misses so far (both sides combined).
    pub fn cache_misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    /// Fill `block` (`rows × train.rows()`) with kernel rows for the test
    /// `features`, through the cache when one is attached.
    fn kernel_block(
        &self,
        kind: KernelKind,
        features: &Matrix,
        train: &Matrix,
        train_sq: &[f64],
        cache: &Option<KernelRowCache>,
    ) -> Matrix {
        let mut block = Matrix::zeros(features.rows(), train.rows());
        for i in 0..features.rows() {
            let x = features.row(i);
            match cache {
                Some(cache) => {
                    let row = cache.get_or_compute(x, train.rows(), |out| {
                        kernel_row_into(kind, x, train, train_sq, out)
                    });
                    block.row_mut(i).copy_from_slice(&row);
                }
                None => kernel_row_into(kind, x, train, train_sq, block.row_mut(i)),
            }
        }
        block
    }

    /// Predict scores for one batch of test edges. Per-batch cost is the
    /// test-side kernel rows (cache misses only), the family's auxiliary
    /// cross / δ blocks, two small transposes per term, and one pairwise
    /// matvec sharded over the context's threads — the train-side index,
    /// plans, and workspaces are shared by reference, not rebuilt.
    ///
    /// The `K̂`/`Ĝ` blocks go through the per-vertex row cache. The
    /// symmetric family's cross blocks reuse them directly when the trained
    /// side is fully homogeneous (one shared feature matrix — they are equal
    /// bit for bit); otherwise they are computed fresh per batch, since they
    /// evaluate test vertices against the *other* side's train features and
    /// cannot share the per-side caches without poisoning them.
    pub fn predict_batch(&self, test: &Dataset) -> Vec<f64> {
        let khat = self.kernel_block(
            self.kernel_d,
            &test.start_features,
            &self.train_start_features,
            &self.start_sq,
            &self.start_cache,
        );
        let ghat = self.kernel_block(
            self.kernel_t,
            &test.end_features,
            &self.train_end_features,
            &self.end_sq,
            &self.end_cache,
        );
        let (aux_g, aux_k) = match self.pairwise {
            PairwiseKernelKind::Kronecker => (None, None),
            // Fully homogeneous trained side (one shared feature matrix):
            // the cross blocks equal the cached ghat/khat bit for bit, so
            // clone them instead of paying two more kernel GEMMs per batch.
            PairwiseKernelKind::SymmetricKron | PairwiseKernelKind::AntiSymmetricKron
                if self.train_start_features == self.train_end_features =>
            {
                (Some(ghat.clone()), Some(khat.clone()))
            }
            PairwiseKernelKind::SymmetricKron | PairwiseKernelKind::AntiSymmetricKron => (
                Some(kernel_matrix_threaded(
                    self.kernel_t,
                    &test.end_features,
                    &self.train_start_features,
                    self.threads,
                )),
                Some(kernel_matrix_threaded(
                    self.kernel_d,
                    &test.start_features,
                    &self.train_end_features,
                    self.threads,
                )),
            ),
            PairwiseKernelKind::Cartesian => (
                Some(delta_matrix(&test.end_features, &self.train_end_features)),
                Some(delta_matrix(&test.start_features, &self.train_start_features)),
            ),
        };
        PairwiseOp::prediction_shared(ghat, khat, aux_g, aux_k, test.kron_index(), &self.shared)
            .expect("context built from a valid model")
            .with_threads(self.threads)
            .predict(&self.dual_coef)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    fn toy_model_and_test(seed: u64, kernel: KernelKind) -> (DualModel, Dataset) {
        let mut rng = Pcg32::seeded(seed);
        let (m, q, n) = (6, 5, 14);
        let model = DualModel {
            dual_coef: rng.normal_vec(n),
            train_start_features: Matrix::from_fn(m, 3, |_, _| rng.normal()),
            train_end_features: Matrix::from_fn(q, 2, |_, _| rng.normal()),
            train_idx: KronIndex::new(
                (0..n).map(|_| rng.below(q) as u32).collect(),
                (0..n).map(|_| rng.below(m) as u32).collect(),
            ),
            kernel_d: kernel,
            kernel_t: kernel,
            pairwise: PairwiseKernelKind::Kronecker,
        };
        let (u, v, t) = (4, 3, 9);
        let test = Dataset {
            start_features: Matrix::from_fn(u, 3, |_, _| rng.normal()),
            end_features: Matrix::from_fn(v, 2, |_, _| rng.normal()),
            start_idx: (0..t).map(|_| rng.below(u) as u32).collect(),
            end_idx: (0..t).map(|_| rng.below(v) as u32).collect(),
            labels: vec![0.0; t],
            name: "test".into(),
        };
        (model, test)
    }

    #[test]
    fn fast_predict_equals_explicit_decision_function() {
        for kernel in [KernelKind::Linear, KernelKind::Gaussian { gamma: 0.4 }] {
            let (model, test) = toy_model_and_test(300, kernel);
            let fast = model.predict(&test);
            let slow = model.predict_explicit(&test);
            assert_allclose(&fast, &slow, 1e-9, 1e-9);
        }
    }

    /// A homogeneous model/test pair (both roles share one 2-d feature
    /// space) so every pairwise family is valid.
    fn homogeneous_model_and_test(seed: u64, pairwise: PairwiseKernelKind) -> (DualModel, Dataset) {
        let mut rng = Pcg32::seeded(seed);
        let (v, n) = (6, 16);
        let features = Matrix::from_fn(v, 2, |_, _| rng.normal());
        let model = DualModel {
            dual_coef: rng.normal_vec(n),
            train_start_features: features.clone(),
            train_end_features: features,
            train_idx: KronIndex::new(
                (0..n).map(|_| rng.below(v) as u32).collect(),
                (0..n).map(|_| rng.below(v) as u32).collect(),
            ),
            kernel_d: KernelKind::Gaussian { gamma: 0.3 },
            kernel_t: KernelKind::Gaussian { gamma: 0.3 },
            pairwise,
        };
        let (tv, t) = (4, 10);
        let test_features = Matrix::from_fn(tv, 2, |_, _| rng.normal());
        let test = Dataset {
            start_features: test_features.clone(),
            end_features: test_features,
            start_idx: (0..t).map(|_| rng.below(tv) as u32).collect(),
            end_idx: (0..t).map(|_| rng.below(tv) as u32).collect(),
            labels: vec![0.0; t],
            name: "homo-test".into(),
        };
        (model, test)
    }

    #[test]
    fn pairwise_fast_predict_equals_explicit_decision_function() {
        for (seed, pairwise) in [
            (320, PairwiseKernelKind::SymmetricKron),
            (321, PairwiseKernelKind::AntiSymmetricKron),
            (322, PairwiseKernelKind::Cartesian),
        ] {
            let (model, test) = homogeneous_model_and_test(seed, pairwise);
            let fast = model.predict(&test);
            let slow = model.predict_explicit(&test);
            assert_allclose(&fast, &slow, 1e-9, 1e-9);
        }
    }

    #[test]
    fn pairwise_context_matches_direct_predict() {
        // The serving context's shared-plan path must agree with the direct
        // per-batch operator for every family (no zero duals → same branch).
        for (seed, pairwise) in [
            (330, PairwiseKernelKind::SymmetricKron),
            (331, PairwiseKernelKind::AntiSymmetricKron),
            (332, PairwiseKernelKind::Cartesian),
        ] {
            let (model, test) = homogeneous_model_and_test(seed, pairwise);
            let direct = model.predict(&test);
            for threads in [1, 2] {
                for cache_vertices in [0, 64] {
                    let ctx = model.predict_context(
                        &Compute::threads(threads).with_cache_vertices(cache_vertices),
                    );
                    let cold = ctx.predict_batch(&test);
                    let warm = ctx.predict_batch(&test);
                    assert_allclose(&cold, &direct, 1e-12, 1e-12);
                    assert_eq!(cold, warm, "{pairwise:?} t={threads} c={cache_vertices}");
                }
            }
        }
    }

    #[test]
    fn context_matches_predict_bitwise_without_zero_duals() {
        // No zero coefficients → pruning is a no-op → the context must be
        // bitwise identical to DualModel::predict, cold or warm, any threads.
        for kernel in [KernelKind::Linear, KernelKind::Gaussian { gamma: 0.4 }] {
            let (model, test) = toy_model_and_test(310, kernel);
            let direct = model.predict(&test);
            for threads in [1, 2, 4] {
                for cache_vertices in [0, 64] {
                    let ctx = model.predict_context(
                        &Compute::threads(threads).with_cache_vertices(cache_vertices),
                    );
                    let cold = ctx.predict_batch(&test);
                    let warm = ctx.predict_batch(&test);
                    assert_eq!(cold, direct, "{kernel:?} t={threads} c={cache_vertices}");
                    assert_eq!(warm, direct, "{kernel:?} warm t={threads} c={cache_vertices}");
                }
            }
        }
    }

    #[test]
    fn context_cache_counts_hits_and_misses() {
        let (model, test) = toy_model_and_test(311, KernelKind::Gaussian { gamma: 0.3 });
        let ctx = model.predict_context(&Compute::serial().with_cache_vertices(64));
        assert_eq!(ctx.cache_hits() + ctx.cache_misses(), 0);
        ctx.predict_batch(&test);
        let vertices = test.m() + test.q();
        let cold_misses = ctx.cache_misses();
        assert_eq!(ctx.cache_hits() + cold_misses, vertices, "cold batch looks up every vertex");
        assert!(cold_misses > 0, "a cold cache must miss");
        ctx.predict_batch(&test);
        assert_eq!(ctx.cache_misses(), cold_misses, "warm batch recomputes nothing");
        assert_eq!(ctx.cache_hits() + cold_misses, 2 * vertices);
    }

    #[test]
    fn context_with_tiny_cache_still_correct_under_eviction() {
        let (model, test) = toy_model_and_test(312, KernelKind::Gaussian { gamma: 0.5 });
        let direct = model.predict(&test);
        // evicts on every other vertex
        let ctx = model.predict_context(&Compute::serial().with_cache_vertices(1));
        for round in 0..3 {
            assert_eq!(ctx.predict_batch(&test), direct, "round {round}");
        }
    }

    #[test]
    fn context_prunes_zero_duals() {
        let (mut model, test) = toy_model_and_test(313, KernelKind::Gaussian { gamma: 0.2 });
        for i in 0..model.dual_coef.len() {
            if i % 3 == 0 {
                model.dual_coef[i] = 0.0;
            }
        }
        let ctx = model.predict_context(&Compute::serial().with_cache_vertices(0));
        assert_eq!(ctx.nnz(), model.nnz());
        // pruning may flip the Algorithm-1 branch → allclose, not bitwise
        assert_allclose(&ctx.predict_batch(&test), &model.predict(&test), 1e-10, 1e-10);
    }

    #[test]
    fn predict_path_columns_match_single_predictions_bitwise() {
        let (model, test) = toy_model_and_test(314, KernelKind::Gaussian { gamma: 0.3 });
        let mut rng = Pcg32::seeded(315);
        // three coefficient sets over the same training side
        let models: Vec<DualModel> = (0..3)
            .map(|_| DualModel {
                dual_coef: rng.normal_vec(model.dual_coef.len()),
                ..model.clone()
            })
            .collect();
        let batched = predict_path(&models, &test).unwrap();
        assert_eq!(batched.len(), 3);
        for (j, scores) in batched.iter().enumerate() {
            assert_eq!(scores, &models[j].predict(&test), "model {j}");
        }
    }

    #[test]
    fn predict_path_rejects_mismatched_training_sides() {
        let (model, test) = toy_model_and_test(316, KernelKind::Linear);
        assert!(predict_path(&[], &test).is_err());
        // a model with a different kernel cannot share the batched sweep
        let mut diff_kernel = model.clone();
        diff_kernel.kernel_d = KernelKind::Gaussian { gamma: 9.0 };
        assert!(predict_path(&[model, diff_kernel], &test).is_err());
    }

    #[test]
    fn pruned_model_predicts_identically() {
        let (mut model, test) = toy_model_and_test(301, KernelKind::Gaussian { gamma: 0.2 });
        for i in 0..model.dual_coef.len() {
            if i % 2 == 0 {
                model.dual_coef[i] = 0.0;
            }
        }
        let pruned = model.pruned();
        assert_eq!(pruned.dual_coef.len(), model.nnz());
        assert_allclose(&pruned.predict(&test), &model.predict(&test), 1e-10, 1e-10);
    }
}
