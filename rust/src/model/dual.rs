//! Dual Kronecker kernel predictor (§3.1).
//!
//! `f(d,t) = Σᵢ aᵢ · k(d_{rᵢ}, d) · g(t_{sᵢ}, t)` over the training edges.
//! Prediction for a batch of test edges is `R̂(Ĝ⊗K̂)Rᵀa`, computed with the
//! generalized vec trick in `O(min(v‖a‖₀ + m·t, u‖a‖₀ + q·t))` (eq. 5)
//! versus `O(t·‖a‖₀)` for the explicit decision function (eq. 6) — the
//! comparison of Fig. 6 (middle).

use crate::data::Dataset;
use crate::gvt::{KronIndex, KronPredictOp};
use crate::kernels::{kernel_matrix, kernel_value, KernelKind};
use crate::linalg::Matrix;

/// A trained dual model. Stores the training vertex features (to evaluate
/// test–train kernel blocks), the edge index, and the dual coefficients.
#[derive(Debug, Clone)]
pub struct DualModel {
    /// Dual coefficients `a ∈ Rⁿ` (sparse for SVM: many exact zeros).
    pub dual_coef: Vec<f64>,
    /// Training start-vertex features (`m × d`).
    pub train_start_features: Matrix,
    /// Training end-vertex features (`q × r`).
    pub train_end_features: Matrix,
    /// Training edge index: `left` = end-vertex, `right` = start-vertex.
    pub train_idx: KronIndex,
    /// Start-vertex kernel `k`.
    pub kernel_d: KernelKind,
    /// End-vertex kernel `g`.
    pub kernel_t: KernelKind,
}

impl DualModel {
    /// Number of non-zero dual coefficients (`‖a‖₀`; SVM support size).
    pub fn nnz(&self) -> usize {
        self.dual_coef.iter().filter(|&&a| a != 0.0).count()
    }

    /// Drop explicit zeros from the model: prunes coefficients and the edge
    /// index so prediction cost scales with `‖a‖₀` (the sparse shortcut the
    /// paper applies to SVM predictors).
    pub fn pruned(&self) -> DualModel {
        let keep: Vec<usize> =
            (0..self.dual_coef.len()).filter(|&i| self.dual_coef[i] != 0.0).collect();
        DualModel {
            dual_coef: keep.iter().map(|&i| self.dual_coef[i]).collect(),
            train_start_features: self.train_start_features.clone(),
            train_end_features: self.train_end_features.clone(),
            train_idx: KronIndex::new(
                keep.iter().map(|&i| self.train_idx.left[i]).collect(),
                keep.iter().map(|&i| self.train_idx.right[i]).collect(),
            ),
            kernel_d: self.kernel_d,
            kernel_t: self.kernel_t,
        }
    }

    /// Build the prediction operator for a batch of test edges. Useful when
    /// predicting repeatedly for the same test vertices (serving).
    pub fn predict_op(&self, test: &Dataset) -> KronPredictOp {
        let khat = kernel_matrix(self.kernel_d, &test.start_features, &self.train_start_features);
        let ghat = kernel_matrix(self.kernel_t, &test.end_features, &self.train_end_features);
        KronPredictOp::new(ghat, khat, test.kron_index(), self.train_idx.clone())
    }

    /// Predict scores for all edges of `test` via the generalized vec trick.
    pub fn predict(&self, test: &Dataset) -> Vec<f64> {
        self.predict_op(test).predict(&self.dual_coef)
    }

    /// [`DualModel::predict`] with the GVT matvec sharded over `threads`
    /// worker threads (`0` = all cores, `1` = serial). Scores are bitwise
    /// identical to the serial path for every thread count.
    pub fn predict_threaded(&self, test: &Dataset, threads: usize) -> Vec<f64> {
        self.predict_op(test).with_threads(threads).predict(&self.dual_coef)
    }

    /// Explicit ("Baseline") decision function: evaluates the edge kernel
    /// between every test edge and every support vector, `O(t·‖a‖₀)` kernel
    /// evaluations — the decision function a standard kernel-SVM package
    /// uses. Kept for the Fig. 6 prediction-time comparison and as a
    /// correctness oracle.
    pub fn predict_explicit(&self, test: &Dataset) -> Vec<f64> {
        let mut out = vec![0.0; test.n_edges()];
        let sv: Vec<usize> =
            (0..self.dual_coef.len()).filter(|&i| self.dual_coef[i] != 0.0).collect();
        for h in 0..test.n_edges() {
            let d_feat = test.start_features.row(test.start_idx[h] as usize);
            let t_feat = test.end_features.row(test.end_idx[h] as usize);
            let mut acc = 0.0;
            for &i in &sv {
                let si = self.train_idx.right[i] as usize; // start vertex
                let ei = self.train_idx.left[i] as usize; // end vertex
                let kd = kernel_value(self.kernel_d, self.train_start_features.row(si), d_feat);
                let gt = kernel_value(self.kernel_t, self.train_end_features.row(ei), t_feat);
                acc += self.dual_coef[i] * kd * gt;
            }
            out[h] = acc;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    fn toy_model_and_test(seed: u64, kernel: KernelKind) -> (DualModel, Dataset) {
        let mut rng = Pcg32::seeded(seed);
        let (m, q, n) = (6, 5, 14);
        let model = DualModel {
            dual_coef: rng.normal_vec(n),
            train_start_features: Matrix::from_fn(m, 3, |_, _| rng.normal()),
            train_end_features: Matrix::from_fn(q, 2, |_, _| rng.normal()),
            train_idx: KronIndex::new(
                (0..n).map(|_| rng.below(q) as u32).collect(),
                (0..n).map(|_| rng.below(m) as u32).collect(),
            ),
            kernel_d: kernel,
            kernel_t: kernel,
        };
        let (u, v, t) = (4, 3, 9);
        let test = Dataset {
            start_features: Matrix::from_fn(u, 3, |_, _| rng.normal()),
            end_features: Matrix::from_fn(v, 2, |_, _| rng.normal()),
            start_idx: (0..t).map(|_| rng.below(u) as u32).collect(),
            end_idx: (0..t).map(|_| rng.below(v) as u32).collect(),
            labels: vec![0.0; t],
            name: "test".into(),
        };
        (model, test)
    }

    #[test]
    fn fast_predict_equals_explicit_decision_function() {
        for kernel in [KernelKind::Linear, KernelKind::Gaussian { gamma: 0.4 }] {
            let (model, test) = toy_model_and_test(300, kernel);
            let fast = model.predict(&test);
            let slow = model.predict_explicit(&test);
            assert_allclose(&fast, &slow, 1e-9, 1e-9);
        }
    }

    #[test]
    fn pruned_model_predicts_identically() {
        let (mut model, test) = toy_model_and_test(301, KernelKind::Gaussian { gamma: 0.2 });
        for i in 0..model.dual_coef.len() {
            if i % 2 == 0 {
                model.dual_coef[i] = 0.0;
            }
        }
        let pruned = model.pruned();
        assert_eq!(pruned.dual_coef.len(), model.nnz());
        assert_allclose(&pruned.predict(&test), &model.predict(&test), 1e-10, 1e-10);
    }
}
