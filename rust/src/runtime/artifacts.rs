//! Artifact registry: discovers AOT-compiled HLO artifacts via
//! `artifacts/manifest.json`, compiles them lazily on the PJRT CPU client,
//! and exposes typed wrappers (padding inputs to the artifact's static
//! shapes, f64↔f32 conversion at the boundary).
//!
//! Artifacts are produced once by `make artifacts` (`python/compile/aot.py`);
//! the Rust binary is self-contained afterwards. Every caller must degrade
//! gracefully when the registry is absent — the native GVT path is always
//! available.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use super::pjrt::{Arg, PjrtContext, PjrtExecutable};
use super::{Result, RuntimeError};
use crate::gvt::KronIndex;
use crate::linalg::Matrix;
use crate::util::json::Json;

/// One artifact entry from the manifest.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    /// Artifact name (manifest key, also the compile-cache key).
    pub name: String,
    /// Artifact kind (`kron_mv`, `gaussian_kernel`, `ridge_train`, …).
    pub kind: String,
    /// HLO-text file name relative to the artifact directory.
    pub file: String,
    /// Static dimensions (e.g. m, q, n, iters, rows, cols, dim).
    pub dims: HashMap<String, usize>,
}

impl ArtifactSpec {
    /// Static dimension by key (0 when absent).
    pub fn dim(&self, key: &str) -> usize {
        *self.dims.get(key).unwrap_or(&0)
    }
}

/// Parsed `manifest.json`.
#[derive(Debug, Clone)]
pub struct ArtifactManifest {
    /// All artifact entries, in manifest order.
    pub artifacts: Vec<ArtifactSpec>,
}

impl ArtifactManifest {
    /// Parse `manifest.json` under `dir`.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            RuntimeError::msg(format!("reading {path:?} (run `make artifacts`): {e}"))
        })?;
        let json =
            Json::parse(&text).map_err(|e| RuntimeError::msg(format!("parsing manifest: {e}")))?;
        let mut artifacts = Vec::new();
        for item in json.get("artifacts").and_then(|a| a.as_arr()).unwrap_or(&[]) {
            let name = item.get("name").and_then(|v| v.as_str()).unwrap_or_default().to_string();
            let kind = item.get("kind").and_then(|v| v.as_str()).unwrap_or_default().to_string();
            let file = item.get("file").and_then(|v| v.as_str()).unwrap_or_default().to_string();
            let mut dims = HashMap::new();
            if let Some(obj) = item.as_obj() {
                for (k, v) in obj {
                    if let Some(n) = v.as_f64() {
                        dims.insert(k.clone(), n as usize);
                    }
                }
            }
            artifacts.push(ArtifactSpec { name, kind, file, dims });
        }
        Ok(ArtifactManifest { artifacts })
    }
}

/// Lazily-compiling artifact registry.
pub struct ArtifactRegistry {
    dir: PathBuf,
    /// The parsed manifest (artifact names, kinds, files, dims).
    pub manifest: ArtifactManifest,
    ctx: PjrtContext,
    cache: RefCell<HashMap<String, Rc<PjrtExecutable>>>,
}

impl ArtifactRegistry {
    /// Open a registry rooted at `dir` (usually `artifacts/`).
    pub fn open<P: AsRef<Path>>(dir: P) -> Result<ArtifactRegistry> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = ArtifactManifest::load(&dir)?;
        let ctx = PjrtContext::cpu()?;
        Ok(ArtifactRegistry { dir, manifest, ctx, cache: RefCell::new(HashMap::new()) })
    }

    /// Whether a manifest exists at `dir` (cheap check before `open`).
    pub fn available<P: AsRef<Path>>(dir: P) -> bool {
        dir.as_ref().join("manifest.json").is_file()
    }

    /// Smallest artifact of `kind` whose dims dominate the given minima.
    pub fn find_bucket(&self, kind: &str, minima: &[(&str, usize)]) -> Option<&ArtifactSpec> {
        self.manifest
            .artifacts
            .iter()
            .filter(|a| a.kind == kind && minima.iter().all(|(k, v)| a.dim(k) >= *v))
            .min_by_key(|a| minima.iter().map(|(k, _)| a.dim(k)).product::<usize>())
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn executable(&self, spec: &ArtifactSpec) -> Result<Rc<PjrtExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&spec.name) {
            return Ok(exe.clone());
        }
        let exe = Rc::new(self.ctx.load_hlo_text(self.dir.join(&spec.file))?);
        self.cache.borrow_mut().insert(spec.name.clone(), exe.clone());
        Ok(exe)
    }

    /// `u = R(G⊗K)Rᵀ v` via the PJRT dense path (scatter → MXU GEMMs →
    /// gather; DESIGN.md §Hardware-Adaptation). Pads `K`, `G` and the edge
    /// arrays up to the artifact's static bucket. Numerics are f32.
    ///
    /// `idx` is the usual `(end, start)` Kronecker index of the edges.
    pub fn kron_mv(&self, k: &Matrix, g: &Matrix, idx: &KronIndex, v: &[f64]) -> Result<Vec<f64>> {
        let (m, q, n) = (k.rows(), g.rows(), idx.len());
        let spec = self
            .find_bucket("kron_mv", &[("m", m), ("q", q), ("n", n)])
            .ok_or_else(|| {
                RuntimeError::msg(format!("no kron_mv bucket covers m={m}, q={q}, n={n}"))
            })?
            .clone();
        let (bm, bq, bn) = (spec.dim("m"), spec.dim("q"), spec.dim("n"));
        let exe = self.executable(&spec)?;

        let k_pad = pad_square_f32(k, bm);
        let g_pad = pad_square_f32(g, bq);
        let mut start = vec![0i32; bn];
        let mut end = vec![0i32; bn];
        let mut v_pad = vec![0f32; bn];
        for h in 0..n {
            end[h] = idx.left[h] as i32;
            start[h] = idx.right[h] as i32;
            v_pad[h] = v[h] as f32;
        }
        let outputs = exe.run(&[
            Arg::F32(&k_pad, &[bm as i64, bm as i64]),
            Arg::F32(&g_pad, &[bq as i64, bq as i64]),
            Arg::I32(&start, &[bn as i64]),
            Arg::I32(&end, &[bn as i64]),
            Arg::F32(&v_pad, &[bn as i64]),
        ])?;
        Ok(outputs[0][..n].iter().map(|&x| x as f64).collect())
    }

    /// Gaussian kernel matrix between feature sets via the Pallas pairwise
    /// kernel artifact. Pads rows and feature dim (zero-padding features is
    /// exact for the Gaussian kernel).
    pub fn gaussian_kernel(&self, x1: &Matrix, x2: &Matrix, gamma: f64) -> Result<Matrix> {
        let (r1, r2, d) = (x1.rows(), x2.rows(), x1.cols());
        assert_eq!(x2.cols(), d);
        let spec = self
            .find_bucket("gaussian_kernel", &[("rows", r1), ("cols", r2), ("dim", d)])
            .ok_or_else(|| {
                RuntimeError::msg(format!("no gaussian_kernel bucket covers {r1}x{r2} d={d}"))
            })?
            .clone();
        let (br, bc, bd) = (spec.dim("rows"), spec.dim("cols"), spec.dim("dim"));
        let exe = self.executable(&spec)?;
        let x1p = pad_rect_f32(x1, br, bd);
        let x2p = pad_rect_f32(x2, bc, bd);
        let gamma32 = [gamma as f32];
        let outputs = exe.run(&[
            Arg::F32(&x1p, &[br as i64, bd as i64]),
            Arg::F32(&x2p, &[bc as i64, bd as i64]),
            Arg::F32(&gamma32, &[]),
        ])?;
        let full = &outputs[0];
        let mut out = Matrix::zeros(r1, r2);
        for i in 0..r1 {
            for j in 0..r2 {
                out.set(i, j, full[i * bc + j] as f64);
            }
        }
        Ok(out)
    }

    /// Full fixed-iteration Kronecker ridge training on-device: returns the
    /// dual coefficients for `(R(G⊗K)Rᵀ + λI)a = y` after the artifact's
    /// baked-in number of CG iterations.
    pub fn ridge_train(
        &self,
        k: &Matrix,
        g: &Matrix,
        idx: &KronIndex,
        y: &[f64],
        lambda: f64,
    ) -> Result<Vec<f64>> {
        let (m, q, n) = (k.rows(), g.rows(), idx.len());
        let spec = self
            .find_bucket("ridge_train", &[("m", m), ("q", q), ("n", n)])
            .ok_or_else(|| {
                RuntimeError::msg(format!("no ridge_train bucket covers m={m}, q={q}, n={n}"))
            })?
            .clone();
        let (bm, bq, bn) = (spec.dim("m"), spec.dim("q"), spec.dim("n"));
        let exe = self.executable(&spec)?;

        let k_pad = pad_square_f32(k, bm);
        let g_pad = pad_square_f32(g, bq);
        let mut start = vec![0i32; bn];
        let mut end = vec![0i32; bn];
        let mut y_pad = vec![0f32; bn];
        // Padding edges at (0,0) with y=0 adds rows `λ·a_extra = 0` to the
        // padded system... not exactly: padded edges make the padded kernel
        // submatrix singular-but-regularized; their a stays ~0 and they do
        // not affect real coordinates only if their kernel row is zero.
        // K/G are zero-padded, so padded edges reference vertex 0 with
        // K[0,0]≠0 — instead we point padded edges at the *padded* vertex
        // index (zero kernel row), making them exactly inert.
        let pad_start = (bm - 1) as i32;
        let pad_end = (bq - 1) as i32;
        for h in 0..bn {
            if h < n {
                end[h] = idx.left[h] as i32;
                start[h] = idx.right[h] as i32;
                y_pad[h] = y[h] as f32;
            } else {
                start[h] = pad_start;
                end[h] = pad_end;
            }
        }
        // If there is no padded vertex (bm == m), padded edges would alias a
        // real vertex; guard against that combination.
        if bn > n && (bm == m || bq == q) {
            return Err(RuntimeError::msg(format!(
                "ridge_train bucket lacks padding headroom (bm={bm}, m={m}, bq={bq}, q={q})"
            )));
        }
        let lambda32 = [lambda as f32];
        let outputs = exe.run(&[
            Arg::F32(&k_pad, &[bm as i64, bm as i64]),
            Arg::F32(&g_pad, &[bq as i64, bq as i64]),
            Arg::I32(&start, &[bn as i64]),
            Arg::I32(&end, &[bn as i64]),
            Arg::F32(&y_pad, &[bn as i64]),
            Arg::F32(&lambda32, &[]),
        ])?;
        Ok(outputs[0][..n].iter().map(|&x| x as f64).collect())
    }
}

fn pad_square_f32(m: &Matrix, dim: usize) -> Vec<f32> {
    let mut out = vec![0f32; dim * dim];
    for i in 0..m.rows() {
        let row = m.row(i);
        for j in 0..m.cols() {
            out[i * dim + j] = row[j] as f32;
        }
    }
    out
}

fn pad_rect_f32(m: &Matrix, rows: usize, cols: usize) -> Vec<f32> {
    let mut out = vec![0f32; rows * cols];
    for i in 0..m.rows() {
        let row = m.row(i);
        for j in 0..m.cols() {
            out[i * cols + j] = row[j] as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parsing() {
        let dir = std::env::temp_dir().join("kronvt_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"version": 1, "artifacts": [
                {"name": "kron_mv_a", "kind": "kron_mv", "file": "a.hlo.txt", "m": 64, "q": 64, "n": 1024},
                {"name": "kron_mv_b", "kind": "kron_mv", "file": "b.hlo.txt", "m": 128, "q": 128, "n": 4096}
            ]}"#,
        )
        .unwrap();
        let manifest = ArtifactManifest::load(&dir).unwrap();
        assert_eq!(manifest.artifacts.len(), 2);
        assert_eq!(manifest.artifacts[0].dim("m"), 64);
        assert_eq!(manifest.artifacts[1].kind, "kron_mv");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn padding_helpers() {
        let m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let p = pad_square_f32(&m, 3);
        assert_eq!(p.len(), 9);
        assert_eq!(p[0], 1.0);
        assert_eq!(p[1], 2.0);
        assert_eq!(p[2], 0.0);
        assert_eq!(p[3], 3.0);
        assert_eq!(p[8], 0.0);
        let r = pad_rect_f32(&m, 2, 4);
        assert_eq!(r[..4], [1.0, 2.0, 0.0, 0.0]);
    }

    // Bucket selection logic without touching PJRT.
    #[test]
    fn bucket_selection_prefers_smallest() {
        let manifest = ArtifactManifest {
            artifacts: vec![
                ArtifactSpec {
                    name: "small".into(),
                    kind: "kron_mv".into(),
                    file: "s.hlo.txt".into(),
                    dims: [("m".to_string(), 64), ("q".to_string(), 64), ("n".to_string(), 1024)]
                        .into_iter()
                        .collect(),
                },
                ArtifactSpec {
                    name: "big".into(),
                    kind: "kron_mv".into(),
                    file: "b.hlo.txt".into(),
                    dims: [("m".to_string(), 256), ("q".to_string(), 256), ("n".to_string(), 16384)]
                        .into_iter()
                        .collect(),
                },
            ],
        };
        // emulate find_bucket logic directly on the manifest
        let pick = |m: usize, q: usize, n: usize| -> Option<String> {
            manifest
                .artifacts
                .iter()
                .filter(|a| a.dim("m") >= m && a.dim("q") >= q && a.dim("n") >= n)
                .min_by_key(|a| a.dim("m") * a.dim("q") * a.dim("n"))
                .map(|a| a.name.clone())
        };
        assert_eq!(pick(60, 60, 1000), Some("small".into()));
        assert_eq!(pick(100, 64, 1024), Some("big".into()));
        assert_eq!(pick(512, 64, 1024), None);
    }
}
