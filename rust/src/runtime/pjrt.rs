//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! The interchange format is **HLO text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
//! (see `/opt/xla-example/README.md` and `python/compile/aot.py`).

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT client (CPU). Construct once and share.
pub struct PjrtContext {
    client: xla::PjRtClient,
}

impl PjrtContext {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<PjrtContext> {
        Ok(PjrtContext { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn platform_name(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO-text file and compile it into an executable.
    pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<PjrtExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling HLO module {path:?}"))?;
        Ok(PjrtExecutable {
            exe,
            name: path.file_stem().map(|s| s.to_string_lossy().to_string()).unwrap_or_default(),
        })
    }
}

/// Typed tensor argument for executions.
pub enum Arg<'a> {
    F32(&'a [f32], &'a [i64]),
    I32(&'a [i32], &'a [i64]),
}

/// A compiled PJRT executable.
pub struct PjrtExecutable {
    exe: xla::PjRtLoadedExecutable,
    name: String,
}

impl PjrtExecutable {
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Execute with typed inputs; returns each output of the result tuple as
    /// a flat f32 vector. (All artifacts are lowered with
    /// `return_tuple=True`, so the single on-device output is a tuple.)
    pub fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = args
            .iter()
            .map(|arg| -> Result<xla::Literal> {
                Ok(match arg {
                    Arg::F32(data, dims) => {
                        xla::Literal::vec1(data).reshape(dims).context("reshaping f32 input")?
                    }
                    Arg::I32(data, dims) => {
                        xla::Literal::vec1(data).reshape(dims).context("reshaping i32 input")?
                    }
                })
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals).context("executing")?;
        let out = result[0][0].to_literal_sync().context("fetching result")?;
        let parts = out.to_tuple().context("decomposing result tuple")?;
        parts
            .into_iter()
            .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    // PJRT round-trip tests live in `rust/tests/artifact_roundtrip.rs`
    // (integration level) because they need `make artifacts` outputs.
}
