//! Thin wrapper over the `xla` crate's PJRT CPU client, gated behind the
//! `pjrt` cargo feature.
//!
//! The interchange format is **HLO text** (not serialized `HloModuleProto`):
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly
//! (see `/opt/xla-example/README.md` and `python/compile/aot.py`).
//!
//! Without the feature (the default — the `xla` crate is not vendored), the
//! same API surface exists but every entry point returns a
//! [`RuntimeError`](super::RuntimeError), so callers keep a single code path
//! and fall back to the native GVT loops.

use std::path::Path;

use super::{Result, RuntimeError};

/// Typed tensor argument for executions.
pub enum Arg<'a> {
    /// f32 buffer with its dimensions.
    F32(&'a [f32], &'a [i64]),
    /// i32 buffer with its dimensions.
    I32(&'a [i32], &'a [i64]),
}

#[cfg(feature = "pjrt")]
mod backed {
    use super::*;

    /// A PJRT client (CPU). Construct once and share.
    pub struct PjrtContext {
        client: xla::PjRtClient,
    }

    impl PjrtContext {
        /// Create a CPU PJRT client.
        pub fn cpu() -> Result<PjrtContext> {
            let client = xla::PjRtClient::cpu()
                .map_err(|e| RuntimeError::msg(format!("creating PJRT CPU client: {e}")))?;
            Ok(PjrtContext { client })
        }

        /// Platform name reported by the client (e.g. "cpu").
        pub fn platform_name(&self) -> String {
            self.client.platform_name()
        }

        /// Load an HLO-text file and compile it into an executable.
        pub fn load_hlo_text<P: AsRef<Path>>(&self, path: P) -> Result<PjrtExecutable> {
            let path = path.as_ref();
            let proto = xla::HloModuleProto::from_text_file(path)
                .map_err(|e| RuntimeError::msg(format!("parsing HLO text {path:?}: {e}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| RuntimeError::msg(format!("compiling HLO module {path:?}: {e}")))?;
            Ok(PjrtExecutable {
                exe,
                name: path.file_stem().map(|s| s.to_string_lossy().to_string()).unwrap_or_default(),
            })
        }
    }

    /// A compiled PJRT executable.
    pub struct PjrtExecutable {
        exe: xla::PjRtLoadedExecutable,
        name: String,
    }

    impl PjrtExecutable {
        /// The artifact's file-stem name.
        pub fn name(&self) -> &str {
            &self.name
        }

        /// Execute with typed inputs; returns each output of the result
        /// tuple as a flat f32 vector. (All artifacts are lowered with
        /// `return_tuple=True`, so the single on-device output is a tuple.)
        pub fn run(&self, args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
            let err = |what: &str| move |e| RuntimeError::msg(format!("{what}: {e}"));
            let literals: Vec<xla::Literal> = args
                .iter()
                .map(|arg| -> Result<xla::Literal> {
                    Ok(match arg {
                        Arg::F32(data, dims) => xla::Literal::vec1(data)
                            .reshape(dims)
                            .map_err(err("reshaping f32 input"))?,
                        Arg::I32(data, dims) => xla::Literal::vec1(data)
                            .reshape(dims)
                            .map_err(err("reshaping i32 input"))?,
                    })
                })
                .collect::<Result<_>>()?;
            let result = self.exe.execute::<xla::Literal>(&literals).map_err(err("executing"))?;
            let out = result[0][0].to_literal_sync().map_err(err("fetching result"))?;
            let parts = out.to_tuple().map_err(err("decomposing result tuple"))?;
            parts
                .into_iter()
                .map(|lit| lit.to_vec::<f32>().map_err(err("reading f32 output")))
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod backed {
    use super::*;

    const DISABLED: &str =
        "kronvt was built without the `pjrt` feature; PJRT artifacts are unavailable \
         (the native GVT path covers every operation)";

    /// A PJRT client (CPU). Stub: construction always fails without the
    /// `pjrt` feature, and callers fall back to the native path.
    pub struct PjrtContext {
        _private: (),
    }

    impl PjrtContext {
        /// Create a CPU PJRT client. Always errors in this build.
        pub fn cpu() -> Result<PjrtContext> {
            Err(RuntimeError::msg(DISABLED))
        }

        /// Platform name reported by the client.
        pub fn platform_name(&self) -> String {
            "disabled".to_string()
        }

        /// Load an HLO-text file and compile it into an executable. Always
        /// errors in this build.
        pub fn load_hlo_text<P: AsRef<Path>>(&self, _path: P) -> Result<PjrtExecutable> {
            Err(RuntimeError::msg(DISABLED))
        }
    }

    /// A compiled PJRT executable (stub: cannot be constructed without the
    /// `pjrt` feature).
    pub struct PjrtExecutable {
        _private: (),
    }

    impl PjrtExecutable {
        /// The artifact's file-stem name.
        pub fn name(&self) -> &str {
            "disabled"
        }

        /// Execute with typed inputs. Unreachable in this build (the stub
        /// executable cannot be constructed), provided for API parity.
        pub fn run(&self, _args: &[Arg<'_>]) -> Result<Vec<Vec<f32>>> {
            Err(RuntimeError::msg(DISABLED))
        }
    }
}

pub use backed::{PjrtContext, PjrtExecutable};

#[cfg(test)]
mod tests {
    // PJRT round-trip tests live in `rust/tests/artifact_roundtrip.rs`
    // (integration level) because they need `make artifacts` outputs.

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn stub_context_reports_disabled() {
        let err = super::PjrtContext::cpu().err().expect("stub must fail");
        assert!(err.to_string().contains("pjrt"));
    }
}
