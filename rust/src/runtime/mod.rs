//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them from the Rust hot path.

pub mod pjrt;
pub mod artifacts;

pub use artifacts::{ArtifactManifest, ArtifactRegistry};
pub use pjrt::PjrtExecutable;
