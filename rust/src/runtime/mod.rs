//! PJRT runtime: load AOT-compiled HLO-text artifacts (produced by
//! `python/compile/aot.py`) and execute them from the Rust hot path.
//!
//! The actual XLA/PJRT backing is gated behind the `pjrt` cargo feature
//! (it needs the external `xla` crate, unavailable in offline builds).
//! Without it, [`pjrt::PjrtContext::cpu`] returns an error and every caller
//! — the [`coordinator::Router`](crate::coordinator::Router), the benches,
//! the CLI — degrades gracefully to the native GVT path, which is always
//! available.

pub mod pjrt;
pub mod artifacts;

pub use artifacts::{ArtifactManifest, ArtifactRegistry};
pub use pjrt::PjrtExecutable;

/// Error raised by the artifact/PJRT runtime (manifest parsing, compilation,
/// execution, or the `pjrt` feature being disabled).
#[derive(Debug, Clone)]
pub struct RuntimeError(String);

impl RuntimeError {
    /// Build an error from any displayable message.
    pub fn msg(msg: impl Into<String>) -> RuntimeError {
        RuntimeError(msg.into())
    }
}

impl std::fmt::Display for RuntimeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for RuntimeError {}

/// Result alias for runtime operations.
pub type Result<T> = std::result::Result<T, RuntimeError>;
