//! Linear models on concatenated `[d, t]` features trained by stochastic
//! gradient descent ([47]) — the scalable baseline of §5.6 (Tables 6–7).
//!
//! `f(d,t) = ⟨w, [d,t]⟩ + b`, losses hinge or logistic, L2 regularization,
//! inverse-scaling learning rate `η_t = η₀ / (1 + η₀ λ t)` (Bottou's
//! schedule). A linear model cannot represent the multiplicative interaction
//! of the checkerboard — which is why the paper reports 0.50 AUC for SGD
//! there — but captures vertex-level "bias" signal on the DTI-style data.

use crate::data::Dataset;
use crate::util::rng::Pcg32;

/// SGD loss selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SgdLossKind {
    /// Hinge loss (L1-SVM).
    Hinge,
    /// Logistic loss.
    Logistic,
}

/// SGD configuration.
#[derive(Debug, Clone, Copy)]
pub struct SgdConfig {
    /// Loss to optimize.
    pub loss: SgdLossKind,
    /// L2 regularization strength.
    pub lambda: f64,
    /// Initial learning rate η₀.
    pub eta0: f64,
    /// Total number of stochastic updates (paper: 10⁶, or ≥ one epoch).
    pub updates: usize,
    /// RNG seed for the sampling order.
    pub seed: u64,
}

impl Default for SgdConfig {
    fn default() -> Self {
        SgdConfig {
            loss: SgdLossKind::Hinge,
            lambda: 1e-4,
            eta0: 0.1,
            updates: 1_000_000,
            seed: 0,
        }
    }
}

/// Trained linear SGD model.
#[derive(Debug, Clone)]
pub struct SgdModel {
    /// Weights over concatenated `[d, t]` features.
    pub w: Vec<f64>,
    /// Unregularized bias term.
    pub bias: f64,
    /// Loss the model was trained with.
    pub loss: SgdLossKind,
}

impl SgdModel {
    /// Train on concatenated features.
    pub fn fit(train: &Dataset, cfg: &SgdConfig) -> Result<SgdModel, String> {
        train.validate()?;
        let n = train.n_edges();
        if n == 0 {
            return Err("empty training set".into());
        }
        let x = train.concat_features();
        let dim = x.cols();
        let y = &train.labels;
        let mut rng = Pcg32::seeded(cfg.seed);

        let mut w = vec![0.0; dim];
        let mut bias = 0.0;
        let updates = cfg.updates.max(n); // at least one epoch in expectation
        for t in 0..updates {
            let i = rng.below(n);
            let xi = x.row(i);
            let eta = cfg.eta0 / (1.0 + cfg.eta0 * cfg.lambda * t as f64);
            let margin_input =
                crate::linalg::vecops::dot(&w, xi) + bias;
            // dL/df for the chosen loss
            let dldf = match cfg.loss {
                SgdLossKind::Hinge => {
                    if y[i] * margin_input < 1.0 {
                        -y[i]
                    } else {
                        0.0
                    }
                }
                SgdLossKind::Logistic => -y[i] / (1.0 + (y[i] * margin_input).exp()),
            };
            // w ← (1 − ηλ) w − η ∂L; bias unregularized
            let shrink = 1.0 - eta * cfg.lambda;
            for k in 0..dim {
                w[k] = shrink * w[k] - eta * dldf * xi[k];
            }
            bias -= eta * dldf;
        }
        Ok(SgdModel { w, bias, loss: cfg.loss })
    }

    /// Predict scores for all edges of `test`.
    pub fn predict(&self, test: &Dataset) -> Vec<f64> {
        let x = test.concat_features();
        (0..x.rows())
            .map(|h| crate::linalg::vecops::dot(&self.w, x.row(h)) + self.bias)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::checkerboard::CheckerboardConfig;
    use crate::eval::auc::auc;
    use crate::linalg::Matrix;

    fn linear_separable(seed: u64, m: usize, q: usize, n: usize) -> Dataset {
        let mut rng = Pcg32::seeded(seed);
        let mut ds = Dataset {
            start_features: Matrix::from_fn(m, 3, |_, _| rng.normal()),
            end_features: Matrix::from_fn(q, 3, |_, _| rng.normal()),
            start_idx: (0..n).map(|_| rng.below(m) as u32).collect(),
            end_idx: (0..n).map(|_| rng.below(q) as u32).collect(),
            labels: vec![0.0; n],
            name: "lin".into(),
        };
        for h in 0..n {
            let d = ds.start_features.row(ds.start_idx[h] as usize);
            let t = ds.end_features.row(ds.end_idx[h] as usize);
            ds.labels[h] = if d[0] - 0.5 * t[1] >= 0.0 { 1.0 } else { -1.0 };
        }
        ds
    }

    #[test]
    fn learns_linear_concept_with_both_losses() {
        let data = linear_separable(800, 30, 30, 400);
        let (train, test) = data.zero_shot_split(0.3, 1);
        for loss in [SgdLossKind::Hinge, SgdLossKind::Logistic] {
            let cfg = SgdConfig { loss, updates: 60_000, ..Default::default() };
            let model = SgdModel::fit(&train, &cfg).unwrap();
            let a = auc(&test.labels, &model.predict(&test));
            assert!(a > 0.9, "{loss:?} AUC={a}");
        }
    }

    #[test]
    fn cannot_learn_checkerboard() {
        // The nonlinearity argument behind Table 6's 0.50 entries.
        let data =
            CheckerboardConfig { m: 50, q: 50, density: 0.5, noise: 0.0, seed: 2, ..Default::default() }.generate();
        let (train, test) = data.zero_shot_split(0.3, 2);
        let cfg = SgdConfig { updates: 50_000, ..Default::default() };
        let model = SgdModel::fit(&train, &cfg).unwrap();
        let a = auc(&test.labels, &model.predict(&test));
        assert!((a - 0.5).abs() < 0.08, "checkerboard AUC should be ~0.5, got {a}");
    }

    #[test]
    fn deterministic_given_seed() {
        let data = linear_separable(801, 10, 10, 50);
        let cfg = SgdConfig { updates: 5_000, ..Default::default() };
        let m1 = SgdModel::fit(&data, &cfg).unwrap();
        let m2 = SgdModel::fit(&data, &cfg).unwrap();
        assert_eq!(m1.w, m2.w);
    }
}
