//! Baseline learners the paper compares against (§5.3–§5.6).
//!
//! * [`explicit_svm`] — a working-set (SMO) dual SVM over the explicitly
//!   evaluated edge kernel, our stand-in for LibSVM [58]: it cannot exploit
//!   the Kronecker structure, so its training cost scales ~quadratically in
//!   the number of edges (the Fig. 6/7 comparison).
//! * [`sgd`] — linear models on concatenated `[d,t]` features trained by
//!   stochastic gradient descent (hinge/logistic), after [47] (Table 6/7).
//! * [`knn`] — k-nearest-neighbour scoring on concatenated features with a
//!   kd-tree for low-dimensional data (Table 6/7).

pub mod explicit_svm;
pub mod sgd;
pub mod knn;

pub use explicit_svm::{ExplicitSvm, ExplicitSvmConfig};
pub use sgd::{SgdConfig, SgdLossKind, SgdModel};
pub use knn::{KnnConfig, KnnModel};
