//! Explicit-kernel dual SVM trained by SMO with maximal-violating-pair
//! working-set selection ([49], the algorithm inside LibSVM [58]).
//!
//! This is the paper's "LibSVM" comparator: a state-of-the-art kernel SVM
//! that sees each edge as an independent example with concatenated features
//! `[d, t]`, evaluates the kernel explicitly, and therefore cannot exploit
//! the shared Kronecker structure. With the Gaussian kernel and equal widths
//! the concatenated-feature kernel *equals* the Kronecker product kernel
//! (§5.1), so its decision function is directly comparable to the Kron
//! methods. Kernel rows are cached LRU-style in f32 (as LibSVM does); cost
//! per SMO iteration is `O(n)` after two row evaluations, and the number of
//! iterations grows superlinearly with `n` — overall the ~quadratic scaling
//! shown in Figs. 6–7.

use crate::data::Dataset;
use crate::kernels::{kernel_value, KernelKind};
use crate::linalg::Matrix;
use crate::model::DualModel;

/// C-SVM configuration (`C ≈ 1/λ` relative to the regularized-risk form).
#[derive(Debug, Clone, Copy)]
pub struct ExplicitSvmConfig {
    /// Box constraint `0 ≤ αᵢ ≤ C`.
    pub c: f64,
    /// Kernel on the concatenated `[d,t]` features.
    pub kernel: KernelKind,
    /// KKT violation tolerance (LibSVM default 1e-3).
    pub tol: f64,
    /// Hard cap on SMO iterations.
    pub max_iters: usize,
    /// Kernel row cache budget in MiB (f32 entries).
    pub cache_mb: usize,
}

impl Default for ExplicitSvmConfig {
    fn default() -> Self {
        ExplicitSvmConfig {
            c: 1.0,
            kernel: KernelKind::Gaussian { gamma: 1.0 },
            tol: 1e-3,
            max_iters: 2_000_000,
            cache_mb: 256,
        }
    }
}

/// LRU-ish cache of f32 kernel rows.
struct RowCache {
    rows: Vec<Option<Vec<f32>>>,
    order: Vec<usize>, // access order, oldest first
    capacity_rows: usize,
}

impl RowCache {
    fn new(n: usize, cache_mb: usize) -> RowCache {
        let bytes = cache_mb * 1024 * 1024;
        let capacity_rows = (bytes / (4 * n.max(1))).max(2);
        RowCache { rows: vec![None; n], order: Vec::new(), capacity_rows }
    }

    fn get_or_compute(&mut self, i: usize, compute: impl FnOnce() -> Vec<f32>) -> &[f32] {
        if self.rows[i].is_none() {
            if self.order.len() >= self.capacity_rows {
                let evict = self.order.remove(0);
                self.rows[evict] = None;
            }
            self.rows[i] = Some(compute());
            self.order.push(i);
        } else {
            // refresh position
            if let Some(pos) = self.order.iter().position(|&x| x == i) {
                let v = self.order.remove(pos);
                self.order.push(v);
            }
        }
        self.rows[i].as_ref().unwrap()
    }
}

/// Trained explicit SVM.
#[derive(Debug, Clone)]
pub struct ExplicitSvm {
    /// Signed coefficients `αᵢ·yᵢ` (the decision-function weights).
    pub coef: Vec<f64>,
    /// Bias term `b`.
    pub bias: f64,
    /// Training concatenated features (support-vector rows are the ones
    /// with non-zero `coef`).
    pub features: Matrix,
    /// Kernel on the concatenated `[d,t]` features.
    pub kernel: KernelKind,
    /// SMO iterations actually executed.
    pub iterations: usize,
}

impl ExplicitSvm {
    /// Train on a dataset with ±1 labels.
    pub fn fit(train: &Dataset, cfg: &ExplicitSvmConfig) -> Result<ExplicitSvm, String> {
        train.validate()?;
        let n = train.n_edges();
        if n < 2 {
            return Err("need at least 2 edges".into());
        }
        let y = &train.labels;
        for &yi in y {
            if yi != 1.0 && yi != -1.0 {
                return Err("SVM requires ±1 labels".into());
            }
        }
        let x = train.concat_features();
        let mut cache = RowCache::new(n, cfg.cache_mb);
        let kernel = cfg.kernel;
        let row = |cache: &mut RowCache, i: usize| -> Vec<f32> {
            // clone out of the cache to avoid holding the borrow; rows are
            // short-lived working data
            cache
                .get_or_compute(i, || {
                    (0..n).map(|j| kernel_value(kernel, x.row(i), x.row(j)) as f32).collect()
                })
                .to_vec()
        };

        let mut alpha = vec![0.0f64; n];
        // gradient of the dual objective: grad_i = y_i f(x_i) - 1 in the
        // standard formulation; track G_i = Σ_j α_j y_j K_ij (so f = G + b).
        let mut g = vec![0.0f64; n];

        let mut iters = 0;
        while iters < cfg.max_iters {
            // Maximal violating pair over the gradient of the dual:
            //   i ∈ argmax_{i ∈ I_up}  -y_i ∇_i,   j ∈ argmin_{j ∈ I_low} -y_j ∇_j
            // with ∇_i = y_i G_i − 1.
            let mut i_up: Option<(usize, f64)> = None;
            let mut j_low: Option<(usize, f64)> = None;
            for t in 0..n {
                let yd = y[t] * g[t] - 1.0; // ∇_t of ½αᵀQα − Σα wrt α_t times y? see below
                let v = -y[t] * yd;
                let in_up = (y[t] > 0.0 && alpha[t] < cfg.c) || (y[t] < 0.0 && alpha[t] > 0.0);
                let in_low = (y[t] > 0.0 && alpha[t] > 0.0) || (y[t] < 0.0 && alpha[t] < cfg.c);
                if in_up && i_up.map_or(true, |(_, best)| v > best) {
                    i_up = Some((t, v));
                }
                if in_low && j_low.map_or(true, |(_, best)| v < best) {
                    j_low = Some((t, v));
                }
            }
            let (i, vi) = match i_up {
                Some(p) => p,
                None => break,
            };
            let (j, vj) = match j_low {
                Some(p) => p,
                None => break,
            };
            if vi - vj < cfg.tol {
                break; // KKT satisfied
            }

            let ki = row(&mut cache, i);
            let kj = row(&mut cache, j);
            let kii = ki[i] as f64;
            let kjj = kj[j] as f64;
            let kij = ki[j] as f64;
            let eta = (kii + kjj - 2.0 * kij).max(1e-12);

            // Work in the s_i = α_i y_i parametrization: the update direction
            // increases s_i and decreases s_j by δ (preserving Σ α_t y_t = 0).
            let delta_unc = (vi - vj) / eta;
            // box limits
            let max_inc_i = if y[i] > 0.0 { cfg.c - alpha[i] } else { alpha[i] };
            let max_dec_j = if y[j] > 0.0 { alpha[j] } else { cfg.c - alpha[j] };
            let delta = delta_unc.min(max_inc_i).min(max_dec_j);
            if delta <= 0.0 {
                break;
            }
            // s_t = α_t·y_t; s_i += δ, s_j −= δ keeps Σ α_t y_t = 0,
            // i.e. α_i += y_i·δ and α_j −= y_j·δ.
            alpha[i] += y[i] * delta;
            alpha[j] -= y[j] * delta;
            // numeric hygiene: clamp
            alpha[i] = alpha[i].clamp(0.0, cfg.c);
            alpha[j] = alpha[j].clamp(0.0, cfg.c);

            // G_t = Σ_s α_s y_s K_st ⇒ ΔG_t = δ(K_it − K_jt)
            for t in 0..n {
                g[t] += delta * (ki[t] as f64 - kj[t] as f64);
            }
            iters += 1;
        }

        // bias from free support vectors (0 < α < C): y_i = G_i + b
        let mut b_sum = 0.0;
        let mut b_cnt = 0usize;
        for t in 0..n {
            if alpha[t] > 1e-8 && alpha[t] < cfg.c - 1e-8 {
                b_sum += y[t] - g[t];
                b_cnt += 1;
            }
        }
        let bias = if b_cnt > 0 {
            b_sum / b_cnt as f64
        } else {
            // fall back to midpoint of the violating-pair bounds
            0.0
        };

        let coef: Vec<f64> = (0..n).map(|t| alpha[t] * y[t]).collect();
        Ok(ExplicitSvm { coef, bias, features: x, kernel: cfg.kernel, iterations: iters })
    }

    /// Number of support vectors.
    pub fn n_support(&self) -> usize {
        self.coef.iter().filter(|&&c| c != 0.0).count()
    }

    /// Explicit ("Baseline") decision function: `O(t·‖α‖₀)` kernel
    /// evaluations over concatenated features.
    pub fn predict(&self, test: &Dataset) -> Vec<f64> {
        let xt = test.concat_features();
        let sv: Vec<usize> = (0..self.coef.len()).filter(|&i| self.coef[i] != 0.0).collect();
        (0..xt.rows())
            .map(|h| {
                let mut acc = self.bias;
                for &i in &sv {
                    acc += self.coef[i] * kernel_value(self.kernel, self.features.row(i), xt.row(h));
                }
                acc
            })
            .collect()
    }

    /// Convert to a Kronecker [`DualModel`] (valid when the kernel is
    /// Gaussian: product kernel ≡ concatenated-feature kernel, §5.1), so the
    /// generalized-vec-trick prediction shortcut can serve this model — the
    /// Fig. 6 (middle) experiment. The bias must be added by the caller
    /// (`predictions + bias`); [`DualModel`] is bias-free.
    pub fn to_dual_model(&self, train: &Dataset) -> Result<DualModel, String> {
        let gamma = match self.kernel {
            KernelKind::Gaussian { gamma } => gamma,
            _ => return Err("only the Gaussian kernel factorizes across [d,t]".into()),
        };
        Ok(DualModel {
            dual_coef: self.coef.clone(),
            train_start_features: train.start_features.clone(),
            train_end_features: train.end_features.clone(),
            train_idx: train.kron_index(),
            kernel_d: KernelKind::Gaussian { gamma },
            kernel_t: KernelKind::Gaussian { gamma },
            pairwise: crate::gvt::PairwiseKernelKind::Kronecker,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::checkerboard::CheckerboardConfig;
    use crate::eval::auc::auc;
    use crate::linalg::vecops::assert_allclose;
    use crate::util::rng::Pcg32;

    fn toy_classification(seed: u64, m: usize, q: usize, n: usize) -> Dataset {
        let mut rng = Pcg32::seeded(seed);
        let mut ds = Dataset {
            start_features: Matrix::from_fn(m, 2, |_, _| rng.normal()),
            end_features: Matrix::from_fn(q, 2, |_, _| rng.normal()),
            start_idx: (0..n).map(|_| rng.below(m) as u32).collect(),
            end_idx: (0..n).map(|_| rng.below(q) as u32).collect(),
            labels: vec![0.0; n],
            name: "toy".into(),
        };
        for h in 0..n {
            let d = ds.start_features.row(ds.start_idx[h] as usize);
            let t = ds.end_features.row(ds.end_idx[h] as usize);
            ds.labels[h] = if d[0] + t[0] >= 0.0 { 1.0 } else { -1.0 };
        }
        ds
    }

    #[test]
    fn solves_separable_problem() {
        let train = toy_classification(700, 10, 10, 60);
        let cfg = ExplicitSvmConfig { c: 10.0, ..Default::default() };
        let svm = ExplicitSvm::fit(&train, &cfg).unwrap();
        let preds = svm.predict(&train);
        let train_auc = auc(&train.labels, &preds);
        assert!(train_auc > 0.95, "train AUC={train_auc}");
    }

    #[test]
    fn kkt_conditions_hold_at_solution() {
        let train = toy_classification(701, 8, 8, 40);
        let cfg = ExplicitSvmConfig { c: 1.0, tol: 1e-4, ..Default::default() };
        let svm = ExplicitSvm::fit(&train, &cfg).unwrap();
        // recompute functional margins
        let f = svm.predict(&train);
        for i in 0..train.n_edges() {
            let alpha = svm.coef[i] * train.labels[i];
            let margin = train.labels[i] * f[i];
            if alpha < 1e-6 {
                assert!(margin > 1.0 - 0.05, "free point with margin {margin}");
            } else if alpha > cfg.c - 1e-6 {
                assert!(margin < 1.0 + 0.05, "bound point with margin {margin}");
            } else {
                assert!((margin - 1.0).abs() < 0.05, "SV margin {margin}");
            }
        }
    }

    #[test]
    fn dual_constraint_preserved() {
        let train = toy_classification(702, 9, 9, 50);
        let svm = ExplicitSvm::fit(&train, &ExplicitSvmConfig::default()).unwrap();
        let sum: f64 = svm.coef.iter().sum(); // Σ α_i y_i
        assert!(sum.abs() < 1e-9, "Σαy = {sum}");
        for (i, &c) in svm.coef.iter().enumerate() {
            let alpha = c * train.labels[i];
            assert!((-1e-9..=1.0 + 1e-9).contains(&alpha), "α[{i}]={alpha}");
        }
    }

    #[test]
    fn gaussian_model_converts_to_kron_predictor() {
        let data = CheckerboardConfig { m: 25, q: 25, density: 0.5, noise: 0.1, feature_range: 5.0, seed: 5, ..Default::default() }
            .generate();
        let (train, test) = data.zero_shot_split(0.3, 3);
        let cfg = ExplicitSvmConfig {
            c: 10.0,
            kernel: KernelKind::Gaussian { gamma: 1.0 },
            ..Default::default()
        };
        let svm = ExplicitSvm::fit(&train, &cfg).unwrap();
        let slow = svm.predict(&test);
        let kron = svm.to_dual_model(&train).unwrap();
        let fast: Vec<f64> = kron.predict(&test).iter().map(|p| p + svm.bias).collect();
        assert_allclose(&fast, &slow, 1e-4, 1e-4);
    }

    #[test]
    fn learns_checkerboard_reasonably() {
        let data = CheckerboardConfig { m: 40, q: 40, density: 0.5, noise: 0.1, feature_range: 6.0, seed: 6, ..Default::default() }
            .generate();
        let (train, test) = data.zero_shot_split(0.3, 8);
        let cfg = ExplicitSvmConfig {
            c: 100.0,
            kernel: KernelKind::Gaussian { gamma: 1.0 },
            ..Default::default()
        };
        let svm = ExplicitSvm::fit(&train, &cfg).unwrap();
        let test_auc = auc(&test.labels, &svm.predict(&test));
        assert!(test_auc > 0.7, "AUC={test_auc}");
    }
}
