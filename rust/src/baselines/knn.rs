//! k-nearest-neighbour scoring on concatenated `[d, t]` features — the
//! neighbourhood baseline of §5.6 ([63], [64]).
//!
//! Scores are the mean label of the k nearest training edges (a smooth
//! score, so AUC is informative). Low-dimensional data (the 2-feature
//! checkerboard) goes through a kd-tree; high-dimensional data falls back to
//! brute force with a bounded-size max-heap — matching the paper's
//! observation that KNN "excels" on 2 features and is uncompetitive on the
//! high-dimensional DTI sets (Table 7).

use crate::data::Dataset;
use crate::linalg::Matrix;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// KNN configuration.
#[derive(Debug, Clone, Copy)]
pub struct KnnConfig {
    /// Number of neighbours.
    pub k: usize,
    /// Use a kd-tree when the feature dimension is at most this.
    pub kd_tree_max_dim: usize,
}

impl Default for KnnConfig {
    fn default() -> Self {
        KnnConfig { k: 5, kd_tree_max_dim: 8 }
    }
}

/// Trained (memorized) KNN model.
pub struct KnnModel {
    features: Matrix,
    labels: Vec<f64>,
    k: usize,
    tree: Option<KdTree>,
}

impl KnnModel {
    /// Memorize the training edges (builds a kd-tree when low-dimensional).
    pub fn fit(train: &Dataset, cfg: &KnnConfig) -> Result<KnnModel, String> {
        train.validate()?;
        if train.n_edges() == 0 {
            return Err("empty training set".into());
        }
        let features = train.concat_features();
        let tree = if features.cols() <= cfg.kd_tree_max_dim {
            Some(KdTree::build(&features))
        } else {
            None
        };
        Ok(KnnModel { features, labels: train.labels.clone(), k: cfg.k.max(1), tree })
    }

    /// Mean-label score of the k nearest training edges for each test edge.
    pub fn predict(&self, test: &Dataset) -> Vec<f64> {
        let x = test.concat_features();
        (0..x.rows()).map(|h| self.score_point(x.row(h))).collect()
    }

    fn score_point(&self, query: &[f64]) -> f64 {
        let idx = match &self.tree {
            Some(tree) => tree.knn(&self.features, query, self.k),
            None => brute_knn(&self.features, query, self.k),
        };
        let s: f64 = idx.iter().map(|&i| self.labels[i]).sum();
        s / idx.len() as f64
    }
}

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

/// (distance, index) max-heap entry.
#[derive(PartialEq)]
struct HeapItem(f64, usize);

impl Eq for HeapItem {}
impl PartialOrd for HeapItem {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for HeapItem {
    fn cmp(&self, other: &Self) -> Ordering {
        self.0.partial_cmp(&other.0).unwrap_or(Ordering::Equal)
    }
}

fn brute_knn(features: &Matrix, query: &[f64], k: usize) -> Vec<usize> {
    let mut heap: BinaryHeap<HeapItem> = BinaryHeap::with_capacity(k + 1);
    for i in 0..features.rows() {
        let d = sq_dist(features.row(i), query);
        if heap.len() < k {
            heap.push(HeapItem(d, i));
        } else if d < heap.peek().unwrap().0 {
            heap.pop();
            heap.push(HeapItem(d, i));
        }
    }
    heap.into_iter().map(|HeapItem(_, i)| i).collect()
}

/// Simple kd-tree over row indices of a feature matrix.
struct KdTree {
    nodes: Vec<KdNode>,
    root: usize,
}

struct KdNode {
    point: usize, // row index
    axis: usize,
    left: Option<usize>,
    right: Option<usize>,
}

impl KdTree {
    fn build(features: &Matrix) -> KdTree {
        let mut idx: Vec<usize> = (0..features.rows()).collect();
        let mut nodes = Vec::with_capacity(features.rows());
        let dim = features.cols();
        let root = Self::build_rec(features, &mut idx[..], 0, dim, &mut nodes).unwrap();
        KdTree { nodes, root }
    }

    fn build_rec(
        features: &Matrix,
        idx: &mut [usize],
        depth: usize,
        dim: usize,
        nodes: &mut Vec<KdNode>,
    ) -> Option<usize> {
        if idx.is_empty() {
            return None;
        }
        let axis = depth % dim;
        idx.sort_by(|&a, &b| {
            features
                .get(a, axis)
                .partial_cmp(&features.get(b, axis))
                .unwrap_or(Ordering::Equal)
        });
        let mid = idx.len() / 2;
        let point = idx[mid];
        let (left_slice, rest) = idx.split_at_mut(mid);
        let right_slice = &mut rest[1..];
        let left = Self::build_rec(features, left_slice, depth + 1, dim, nodes);
        let right = Self::build_rec(features, right_slice, depth + 1, dim, nodes);
        nodes.push(KdNode { point, axis, left, right });
        Some(nodes.len() - 1)
    }

    fn knn(&self, features: &Matrix, query: &[f64], k: usize) -> Vec<usize> {
        let mut heap: BinaryHeap<HeapItem> = BinaryHeap::with_capacity(k + 1);
        self.search(self.root, features, query, k, &mut heap);
        heap.into_iter().map(|HeapItem(_, i)| i).collect()
    }

    fn search(
        &self,
        node_id: usize,
        features: &Matrix,
        query: &[f64],
        k: usize,
        heap: &mut BinaryHeap<HeapItem>,
    ) {
        let node = &self.nodes[node_id];
        let d = sq_dist(features.row(node.point), query);
        if heap.len() < k {
            heap.push(HeapItem(d, node.point));
        } else if d < heap.peek().unwrap().0 {
            heap.pop();
            heap.push(HeapItem(d, node.point));
        }
        let diff = query[node.axis] - features.get(node.point, node.axis);
        let (near, far) = if diff <= 0.0 {
            (node.left, node.right)
        } else {
            (node.right, node.left)
        };
        if let Some(n) = near {
            self.search(n, features, query, k, heap);
        }
        // prune: visit far side only if the splitting plane is closer than
        // the current k-th distance (or the heap is not full)
        let worst = heap.peek().map(|h| h.0).unwrap_or(f64::INFINITY);
        if let Some(f) = far {
            if heap.len() < k || diff * diff < worst {
                self.search(f, features, query, k, heap);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::checkerboard::CheckerboardConfig;
    use crate::eval::auc::auc;
    use crate::util::rng::Pcg32;

    #[test]
    fn kdtree_matches_brute_force() {
        let mut rng = Pcg32::seeded(900);
        let features = Matrix::from_fn(200, 3, |_, _| rng.normal());
        let tree = KdTree::build(&features);
        for _ in 0..25 {
            let query = rng.normal_vec(3);
            let mut a = tree.knn(&features, &query, 7);
            let mut b = brute_knn(&features, &query, 7);
            a.sort_unstable();
            b.sort_unstable();
            // distances must match even if tie-broken differently
            let da: Vec<f64> = a.iter().map(|&i| sq_dist(features.row(i), &query)).collect();
            let db: Vec<f64> = b.iter().map(|&i| sq_dist(features.row(i), &query)).collect();
            let mut da = da;
            let mut db = db;
            da.sort_by(|x, y| x.partial_cmp(y).unwrap());
            db.sort_by(|x, y| x.partial_cmp(y).unwrap());
            for (x, y) in da.iter().zip(&db) {
                assert!((x - y).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn knn_solves_checkerboard() {
        // 2 features → kd-tree path; KNN is strong here (Table 6: 0.68).
        let data =
            CheckerboardConfig { m: 80, q: 80, density: 0.5, noise: 0.05, feature_range: 6.0, seed: 3, ..Default::default() }.generate();
        let (train, test) = data.zero_shot_split(0.3, 4);
        let model = KnnModel::fit(&train, &KnnConfig { k: 9, ..Default::default() }).unwrap();
        let a = auc(&test.labels, &model.predict(&test));
        assert!(a > 0.7, "AUC={a}");
    }

    #[test]
    fn brute_force_path_used_for_high_dim() {
        let mut rng = Pcg32::seeded(901);
        let ds = Dataset {
            start_features: Matrix::from_fn(10, 10, |_, _| rng.normal()),
            end_features: Matrix::from_fn(10, 10, |_, _| rng.normal()),
            start_idx: (0..30).map(|_| rng.below(10) as u32).collect(),
            end_idx: (0..30).map(|_| rng.below(10) as u32).collect(),
            labels: (0..30).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect(),
            name: "hd".into(),
        };
        let model = KnnModel::fit(&ds, &KnnConfig::default()).unwrap();
        assert!(model.tree.is_none());
        let preds = model.predict(&ds);
        assert_eq!(preds.len(), 30);
        // nearest neighbour of a training point is itself → k=1 would give
        // its own label; with k=5 scores stay in [-1, 1]
        assert!(preds.iter().all(|p| (-1.0..=1.0).contains(p)));
    }

    #[test]
    fn scores_are_label_means() {
        let ds = Dataset {
            start_features: Matrix::from_rows(&[&[0.0], &[10.0]]),
            end_features: Matrix::from_rows(&[&[0.0], &[10.0]]),
            start_idx: vec![0, 0, 1, 1],
            end_idx: vec![0, 1, 0, 1],
            labels: vec![1.0, -1.0, -1.0, 1.0],
            name: "t".into(),
        };
        let model = KnnModel::fit(&ds, &KnnConfig { k: 1, ..Default::default() }).unwrap();
        let preds = model.predict(&ds);
        assert_eq!(preds, ds.labels);
    }
}
