//! Labeled bipartite-graph dataset container and zero-shot splits.
//!
//! A dataset is a sequence of edges `(d_{start_h}, t_{end_h}, y_h)` over `m`
//! start vertices (features `D ∈ R^{m×d}`) and `q` end vertices
//! (`T ∈ R^{q×r}`). Vertices are referenced by index; edges may repeat
//! vertices arbitrarily (the "Dependent" regime that the generalized vec
//! trick exploits).

use crate::gvt::KronIndex;
use crate::linalg::Matrix;
use crate::util::rng::Pcg32;

/// A labeled bipartite graph with vertex features.
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Start-vertex features, `m × d`.
    pub start_features: Matrix,
    /// End-vertex features, `q × r`.
    pub end_features: Matrix,
    /// Edge start-vertex indices (into `start_features` rows).
    pub start_idx: Vec<u32>,
    /// Edge end-vertex indices (into `end_features` rows).
    pub end_idx: Vec<u32>,
    /// Edge labels (±1 for classification, real for regression).
    pub labels: Vec<f64>,
    /// Dataset name for reports.
    pub name: String,
}

/// Table-5-style summary statistics.
#[derive(Debug, Clone, PartialEq)]
pub struct DatasetStats {
    /// Labeled edges `n`.
    pub edges: usize,
    /// Edges with label > 0.
    pub positives: usize,
    /// Edges with label ≤ 0.
    pub negatives: usize,
    /// Start vertices `m`.
    pub start_vertices: usize,
    /// End vertices `q`.
    pub end_vertices: usize,
}

impl Dataset {
    /// Validate internal consistency (index bounds, lengths).
    pub fn validate(&self) -> Result<(), String> {
        if self.start_idx.len() != self.end_idx.len() || self.start_idx.len() != self.labels.len()
        {
            return Err("edge arrays have mismatched lengths".into());
        }
        let m = self.start_features.rows() as u32;
        let q = self.end_features.rows() as u32;
        for (h, (&s, &e)) in self.start_idx.iter().zip(&self.end_idx).enumerate() {
            if s >= m {
                return Err(format!("edge {h}: start index {s} ≥ m={m}"));
            }
            if e >= q {
                return Err(format!("edge {h}: end index {e} ≥ q={q}"));
            }
        }
        Ok(())
    }

    /// Number of labeled edges `n`.
    pub fn n_edges(&self) -> usize {
        self.labels.len()
    }

    /// Number of start vertices `m`.
    pub fn m(&self) -> usize {
        self.start_features.rows()
    }

    /// Number of end vertices `q`.
    pub fn q(&self) -> usize {
        self.end_features.rows()
    }

    /// The Kronecker index of the edges: `left` = end-vertex index (selects
    /// rows of `G`), `right` = start-vertex index (rows of `K`) — matching
    /// the `G ⊗ K` ordering used throughout the crate.
    pub fn kron_index(&self) -> KronIndex {
        KronIndex::new(self.end_idx.clone(), self.start_idx.clone())
    }

    /// Table-5-style statistics.
    pub fn stats(&self) -> DatasetStats {
        let positives = self.labels.iter().filter(|&&y| y > 0.0).count();
        DatasetStats {
            edges: self.n_edges(),
            positives,
            negatives: self.n_edges() - positives,
            start_vertices: self.m(),
            end_vertices: self.q(),
        }
    }

    /// Graph density `n / (m·q)`.
    pub fn density(&self) -> f64 {
        self.n_edges() as f64 / (self.m() as f64 * self.q() as f64)
    }

    /// Whether both edge roles index one shared vertex set (identical
    /// feature matrices) — the homogeneous-graph setting of
    /// [`crate::data::checkerboard::HomogeneousConfig`]. Splits use one
    /// shared vertex mask in this case (see [`Dataset::zero_shot_split`]).
    pub fn is_homogeneous(&self) -> bool {
        self.start_features == self.end_features
    }

    /// Build a new dataset from a subset of edge positions, compacting the
    /// vertex sets to those incident to at least one kept edge.
    pub fn subset_by_edges(&self, edge_pos: &[usize], name: &str) -> Dataset {
        let mut start_map = vec![u32::MAX; self.m()];
        let mut end_map = vec![u32::MAX; self.q()];
        let mut kept_starts = Vec::new();
        let mut kept_ends = Vec::new();
        let mut start_idx = Vec::with_capacity(edge_pos.len());
        let mut end_idx = Vec::with_capacity(edge_pos.len());
        let mut labels = Vec::with_capacity(edge_pos.len());
        for &h in edge_pos {
            let s = self.start_idx[h] as usize;
            let e = self.end_idx[h] as usize;
            if start_map[s] == u32::MAX {
                start_map[s] = kept_starts.len() as u32;
                kept_starts.push(s);
            }
            if end_map[e] == u32::MAX {
                end_map[e] = kept_ends.len() as u32;
                kept_ends.push(e);
            }
            start_idx.push(start_map[s]);
            end_idx.push(end_map[e]);
            labels.push(self.labels[h]);
        }
        Dataset {
            start_features: self.start_features.select_rows(&kept_starts),
            end_features: self.end_features.select_rows(&kept_ends),
            start_idx,
            end_idx,
            labels,
            name: name.to_string(),
        }
    }

    /// Random subsample of `n` edges (for learning-curve benchmarks).
    pub fn subsample_edges(&self, n: usize, seed: u64) -> Dataset {
        let mut rng = Pcg32::seeded(seed);
        let pos = rng.sample_indices(self.n_edges(), n.min(self.n_edges()));
        self.subset_by_edges(&pos, &format!("{}[n={n}]", self.name))
    }

    /// Vertex-disjoint (zero-shot) train/test split: `test_frac` of start
    /// vertices and of end vertices are held out; training edges connect two
    /// retained vertices, test edges connect two held-out vertices, and all
    /// mixed edges are discarded (§5.1, Fig. 2 idea with 2×2 blocks).
    ///
    /// On a **homogeneous** dataset ([`Dataset::is_homogeneous`]) the two
    /// roles share **one** held-out vertex mask. Independent masks would
    /// leak labels there: an undirected pair is stored in both orientations
    /// with one label, and with separate masks a test edge's mirror lands in
    /// training whenever the masks disagree on its endpoints. A shared mask
    /// keeps every pair's orientations in the same fold.
    pub fn zero_shot_split(&self, test_frac: f64, seed: u64) -> (Dataset, Dataset) {
        assert!((0.0..1.0).contains(&test_frac));
        let mut rng = Pcg32::seeded(seed);
        let m_test = ((self.m() as f64) * test_frac).round().max(1.0) as usize;
        let q_test = ((self.q() as f64) * test_frac).round().max(1.0) as usize;
        let start_test = mask_from_indices(self.m(), &rng.sample_indices(self.m(), m_test));
        let end_test = if self.is_homogeneous() {
            start_test.clone()
        } else {
            mask_from_indices(self.q(), &rng.sample_indices(self.q(), q_test))
        };

        let mut train_edges = Vec::new();
        let mut test_edges = Vec::new();
        for h in 0..self.n_edges() {
            let s_test = start_test[self.start_idx[h] as usize];
            let e_test = end_test[self.end_idx[h] as usize];
            match (s_test, e_test) {
                (false, false) => train_edges.push(h),
                (true, true) => test_edges.push(h),
                _ => {} // discarded: connects train and test vertices
            }
        }
        (
            self.subset_by_edges(&train_edges, &format!("{}-train", self.name)),
            self.subset_by_edges(&test_edges, &format!("{}-test", self.name)),
        )
    }

    /// The 9-fold zero-shot cross-validation of Fig. 2: start and end vertex
    /// indices are each partitioned into 3 groups, inducing 9 blocks. Each
    /// round uses one block as the test fold and the 4 blocks sharing no row
    /// or column group as training; the remaining 4 blocks are discarded.
    /// Returns `(train_dataset, test_dataset)` pairs.
    ///
    /// On a **homogeneous** dataset ([`Dataset::is_homogeneous`]) both roles
    /// share one 3-way vertex grouping and only the **3 diagonal folds** are
    /// produced: off-diagonal blocks would put a test pair's mirror
    /// orientation into the training block (label leakage), while a diagonal
    /// fold keeps both orientations of every pair on the same side.
    pub fn ninefold_cv(&self, seed: u64) -> Vec<(Dataset, Dataset)> {
        let mut rng = Pcg32::seeded(seed);
        let start_group = random_groups(self.m(), 3, &mut rng);
        let homogeneous = self.is_homogeneous();
        let end_group =
            if homogeneous { start_group.clone() } else { random_groups(self.q(), 3, &mut rng) };

        let mut folds = Vec::with_capacity(9);
        for gi in 0..3u8 {
            for gj in 0..3u8 {
                if homogeneous && gi != gj {
                    continue; // off-diagonal blocks leak mirrored labels
                }
                let mut train_edges = Vec::new();
                let mut test_edges = Vec::new();
                for h in 0..self.n_edges() {
                    let sg = start_group[self.start_idx[h] as usize];
                    let eg = end_group[self.end_idx[h] as usize];
                    if sg == gi && eg == gj {
                        test_edges.push(h);
                    } else if sg != gi && eg != gj {
                        train_edges.push(h);
                    }
                }
                if train_edges.is_empty() || test_edges.is_empty() {
                    continue;
                }
                folds.push((
                    self.subset_by_edges(&train_edges, &format!("{}-cv{}{}-tr", self.name, gi, gj)),
                    self.subset_by_edges(&test_edges, &format!("{}-cv{}{}-te", self.name, gi, gj)),
                ));
            }
        }
        folds
    }

    /// Concatenated `[d, t]` feature matrix of the edges (what the SGD and
    /// KNN baselines operate on, §5.6).
    pub fn concat_features(&self) -> Matrix {
        let d = self.start_features.cols();
        let r = self.end_features.cols();
        let mut out = Matrix::zeros(self.n_edges(), d + r);
        for h in 0..self.n_edges() {
            let row = out.row_mut(h);
            row[..d].copy_from_slice(self.start_features.row(self.start_idx[h] as usize));
            row[d..].copy_from_slice(self.end_features.row(self.end_idx[h] as usize));
        }
        out
    }
}

fn mask_from_indices(n: usize, idx: &[usize]) -> Vec<bool> {
    let mut mask = vec![false; n];
    for &i in idx {
        mask[i] = true;
    }
    mask
}

/// Random balanced assignment of `n` items to `k` groups.
fn random_groups(n: usize, k: u8, rng: &mut Pcg32) -> Vec<u8> {
    let mut groups: Vec<u8> = (0..n).map(|i| (i % k as usize) as u8).collect();
    rng.shuffle(&mut groups);
    groups
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_dataset(m: usize, q: usize, n: usize, seed: u64) -> Dataset {
        let mut rng = Pcg32::seeded(seed);
        let ds = Dataset {
            start_features: Matrix::from_fn(m, 3, |_, _| rng.normal()),
            end_features: Matrix::from_fn(q, 2, |_, _| rng.normal()),
            start_idx: (0..n).map(|_| rng.below(m) as u32).collect(),
            end_idx: (0..n).map(|_| rng.below(q) as u32).collect(),
            labels: (0..n).map(|_| if rng.bernoulli(0.3) { 1.0 } else { -1.0 }).collect(),
            name: "toy".into(),
        };
        ds.validate().unwrap();
        ds
    }

    #[test]
    fn stats_and_density() {
        let ds = toy_dataset(10, 8, 40, 1);
        let st = ds.stats();
        assert_eq!(st.edges, 40);
        assert_eq!(st.positives + st.negatives, 40);
        assert_eq!(st.start_vertices, 10);
        assert!((ds.density() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn subset_compacts_vertices() {
        let ds = toy_dataset(20, 20, 10, 2);
        let sub = ds.subset_by_edges(&[0, 3, 7], "sub");
        sub.validate().unwrap();
        assert_eq!(sub.n_edges(), 3);
        assert!(sub.m() <= 3);
        assert!(sub.q() <= 3);
        // features must follow their vertices
        for h in 0..3 {
            let orig_h = [0, 3, 7][h];
            let orig_row = ds.start_features.row(ds.start_idx[orig_h] as usize);
            let new_row = sub.start_features.row(sub.start_idx[h] as usize);
            assert_eq!(orig_row, new_row);
            assert_eq!(ds.labels[orig_h], sub.labels[h]);
        }
    }

    #[test]
    fn zero_shot_split_is_vertex_disjoint() {
        let ds = toy_dataset(30, 25, 300, 3);
        let (train, test) = ds.zero_shot_split(0.3, 7);
        train.validate().unwrap();
        test.validate().unwrap();
        assert!(train.n_edges() > 0);
        assert!(test.n_edges() > 0);
        // No feature row of the test vertices may appear among train vertices.
        for i in 0..test.m() {
            for j in 0..train.m() {
                assert_ne!(test.start_features.row(i), train.start_features.row(j));
            }
        }
        for i in 0..test.q() {
            for j in 0..train.q() {
                assert_ne!(test.end_features.row(i), train.end_features.row(j));
            }
        }
    }

    #[test]
    fn ninefold_cv_has_nine_disjoint_folds() {
        let ds = toy_dataset(30, 30, 500, 4);
        let folds = ds.ninefold_cv(11);
        assert_eq!(folds.len(), 9);
        for (train, test) in &folds {
            assert!(train.n_edges() > 0);
            assert!(test.n_edges() > 0);
            // vertex-disjoint: no shared feature rows
            for i in 0..test.m() {
                for j in 0..train.m() {
                    assert_ne!(test.start_features.row(i), train.start_features.row(j));
                }
            }
        }
        // Test folds partition a subset of edges: blocks are disjoint, so the
        // total number of test edges equals n (each edge is in exactly one block).
        let total_test: usize = folds.iter().map(|(_, te)| te.n_edges()).sum();
        assert_eq!(total_test, ds.n_edges());
    }

    fn toy_homogeneous(v: usize, pairs_per_vertex: usize, seed: u64) -> Dataset {
        let mut rng = Pcg32::seeded(seed);
        let features = Matrix::from_fn(v, 2, |_, _| rng.normal());
        let mut start_idx = Vec::new();
        let mut end_idx = Vec::new();
        let mut labels = Vec::new();
        for i in 0..v {
            for j in rng.sample_indices(v, pairs_per_vertex) {
                if j <= i {
                    continue;
                }
                let y = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
                start_idx.push(i as u32);
                end_idx.push(j as u32);
                labels.push(y);
                start_idx.push(j as u32);
                end_idx.push(i as u32);
                labels.push(y);
            }
        }
        Dataset {
            start_features: features.clone(),
            end_features: features,
            start_idx,
            end_idx,
            labels,
            name: "toy-homo".into(),
        }
    }

    /// Edge identities as (start-feature-bits, end-feature-bits) pairs —
    /// robust to the vertex compaction `subset_by_edges` performs.
    fn edge_feature_pairs(ds: &Dataset) -> Vec<(u64, u64)> {
        (0..ds.n_edges())
            .map(|h| {
                let s = ds.start_features.row(ds.start_idx[h] as usize)[0].to_bits();
                let e = ds.end_features.row(ds.end_idx[h] as usize)[0].to_bits();
                (s, e)
            })
            .collect()
    }

    #[test]
    fn homogeneous_zero_shot_split_shares_one_vertex_mask() {
        // Regression: with independent start/end masks, a homogeneous test
        // pair's mirror orientation (same label!) could land in training —
        // label leakage. The shared mask keeps both orientations together.
        let ds = toy_homogeneous(30, 12, 8);
        assert!(ds.is_homogeneous());
        let (train, test) = ds.zero_shot_split(0.3, 7);
        assert!(train.n_edges() > 0 && test.n_edges() > 0);
        let train_pairs: std::collections::HashSet<(u64, u64)> =
            edge_feature_pairs(&train).into_iter().collect();
        for (s, e) in edge_feature_pairs(&test) {
            assert!(!train_pairs.contains(&(s, e)), "test edge present in train");
            assert!(!train_pairs.contains(&(e, s)), "test edge's mirror present in train");
        }
    }

    #[test]
    fn homogeneous_ninefold_cv_uses_diagonal_folds_only() {
        let ds = toy_homogeneous(36, 14, 9);
        let folds = ds.ninefold_cv(11);
        assert_eq!(folds.len(), 3, "homogeneous CV keeps the 3 leak-free diagonal folds");
        for (train, test) in &folds {
            assert!(train.n_edges() > 0 && test.n_edges() > 0);
            let train_pairs: std::collections::HashSet<(u64, u64)> =
                edge_feature_pairs(train).into_iter().collect();
            for (s, e) in edge_feature_pairs(test) {
                assert!(!train_pairs.contains(&(s, e)));
                assert!(!train_pairs.contains(&(e, s)), "mirror leaked into training fold");
            }
        }
    }

    #[test]
    fn concat_features_layout() {
        let ds = toy_dataset(5, 5, 8, 5);
        let cf = ds.concat_features();
        assert_eq!(cf.rows(), 8);
        assert_eq!(cf.cols(), 5);
        let h = 3;
        assert_eq!(
            &cf.row(h)[..3],
            ds.start_features.row(ds.start_idx[h] as usize)
        );
        assert_eq!(&cf.row(h)[3..], ds.end_features.row(ds.end_idx[h] as usize));
    }

    #[test]
    fn subsample_respects_n() {
        let ds = toy_dataset(10, 10, 50, 6);
        let sub = ds.subsample_edges(20, 1);
        assert_eq!(sub.n_edges(), 20);
        let over = ds.subsample_edges(500, 1);
        assert_eq!(over.n_edges(), 50);
    }
}
