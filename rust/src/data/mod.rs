//! Bipartite-graph datasets: container, generators, and zero-shot splits.
//!
//! * [`dataset`] — the labeled edge-list container with vertex feature
//!   matrices, plus vertex-disjoint (zero-shot) train/test splitting and the
//!   9-fold cross-validation scheme of Fig. 2.
//! * [`checkerboard`] — the Checkerboard simulation of §5.1 (exact), plus
//!   the homogeneous-graph (single vertex set, symmetric labels) variant
//!   for the pairwise kernel families.
//! * [`dti`] — synthetic drug–target interaction data matching the Table 5
//!   dataset shapes (Ki, GPCR, IC, E); see DESIGN.md §3 for the substitution
//!   rationale.
//! * [`tensor`] — D-way grid datasets ([`TensorDataset`]) and the
//!   spatio-temporal checkerboard generator for tensor-chain workloads.
//! * [`stream`] — chunked [`StreamingEdgeSource`]s (in-memory adapter and
//!   the `kronvt-edges/v1` on-disk format) feeding the stochastic trainer
//!   without ever holding the full edge list in one allocation.

pub mod dataset;
pub mod checkerboard;
pub mod dti;
pub mod tensor;
pub mod stream;

pub use dataset::Dataset;
pub use checkerboard::{CheckerboardConfig, HomogeneousConfig};
pub use dti::DtiConfig;
pub use stream::{
    BinaryEdgeReader, BinaryEdgeWriter, EdgeChunk, InMemorySource, StreamingEdgeSource,
};
pub use tensor::{GridCheckerboardConfig, TensorDataset};
