//! The Checkerboard simulation of §5.1 — a standard nonlinear benchmark for
//! large-scale SVM solvers ([61]).
//!
//! Start and end vertices each carry a single feature drawn uniformly from
//! `(0, 100)`. The label of edge `(d, t)` is `+1` when `⌊d⌋` and `⌊t⌋` have
//! equal parity, `−1` otherwise, and each label is flipped with probability
//! `noise` (0.2 in the paper). A fraction `density` (0.25 in the paper) of
//! all `m·q` possible edges is labeled; sampling is per-start-vertex so the
//! edge count is exact and generation streams in O(n).
//!
//! [`HomogeneousConfig`] additionally generates the **homogeneous-graph**
//! variant — one shared vertex set on both edge sides with symmetric labels
//! (the protein–protein / drug–drug setting) — to exercise the symmetric
//! pairwise kernel family end to end.

use super::dataset::Dataset;
use crate::linalg::Matrix;
use crate::util::rng::Pcg32;

/// Configuration for checkerboard generation.
#[derive(Debug, Clone, Copy)]
pub struct CheckerboardConfig {
    /// Number of start vertices (paper: 1000 for Checker, 6400 for Checker+).
    pub m: usize,
    /// Number of end vertices (paper: equal to `m`).
    pub q: usize,
    /// Fraction of the `m·q` possible edges that receive labels (paper: 0.25).
    pub density: f64,
    /// Label-flip probability (paper: 0.2).
    pub noise: f64,
    /// Feature range: features are uniform in `(0, feature_range)` and the
    /// board has `feature_range²` unit cells (paper: 100). Small tests use a
    /// smaller range so that the vertex density per cell stays high enough
    /// for zero-shot generalization.
    pub feature_range: f64,
    /// RNG seed (features, edge sampling, label noise).
    pub seed: u64,
}

impl Default for CheckerboardConfig {
    fn default() -> Self {
        CheckerboardConfig { m: 1000, q: 1000, density: 0.25, noise: 0.2, feature_range: 100.0, seed: 0 }
    }
}

/// The paper's `Checker` dataset (1000×1000 vertices, 250 000 edges).
pub fn checker(seed: u64) -> CheckerboardConfig {
    CheckerboardConfig { m: 1000, q: 1000, density: 0.25, noise: 0.2, feature_range: 100.0, seed }
}

/// The paper's `Checker+` dataset (6400×6400 vertices, 10 240 000 edges).
pub fn checker_plus(seed: u64) -> CheckerboardConfig {
    CheckerboardConfig { m: 6400, q: 6400, density: 0.25, noise: 0.2, feature_range: 100.0, seed }
}

/// Noise-free checkerboard label for features `(d, t)`.
pub fn true_label(d: f64, t: f64) -> f64 {
    if (d.floor() as i64 + t.floor() as i64) % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

impl CheckerboardConfig {
    /// Number of edges this config will generate.
    pub fn n_edges(&self) -> usize {
        let per_row = ((self.q as f64) * self.density).round() as usize;
        per_row * self.m
    }

    /// Generate the dataset.
    pub fn generate(&self) -> Dataset {
        let mut rng = Pcg32::seeded(self.seed);
        let d_feat: Vec<f64> = rng.uniform_vec(self.m, 0.0, self.feature_range);
        let t_feat: Vec<f64> = rng.uniform_vec(self.q, 0.0, self.feature_range);

        let per_row = ((self.q as f64) * self.density).round() as usize;
        let n = per_row * self.m;
        let mut start_idx = Vec::with_capacity(n);
        let mut end_idx = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);

        for i in 0..self.m {
            // exact sample of `per_row` distinct end vertices
            for j in rng.sample_indices(self.q, per_row) {
                start_idx.push(i as u32);
                end_idx.push(j as u32);
                let mut y = true_label(d_feat[i], t_feat[j]);
                if rng.bernoulli(self.noise) {
                    y = -y;
                }
                labels.push(y);
            }
        }

        Dataset {
            start_features: Matrix::from_vec(self.m, 1, d_feat),
            end_features: Matrix::from_vec(self.q, 1, t_feat),
            start_idx,
            end_idx,
            labels,
            name: format!("checker-{}x{}", self.m, self.q),
        }
    }
}

/// Configuration for the homogeneous (single-vertex-set) checkerboard: both
/// edge roles index one vertex set, every labeled pair appears in **both
/// orientations with one shared label**, and the checkerboard truth
/// `true_label` is already symmetric in its arguments — the canonical
/// workload for the symmetric pairwise kernel
/// ([`PairwiseKernelKind::SymmetricKron`](crate::gvt::PairwiseKernelKind)).
#[derive(Debug, Clone, Copy)]
pub struct HomogeneousConfig {
    /// Number of vertices in the single shared vertex set.
    pub vertices: usize,
    /// Approximate fraction of partners sampled per vertex; each kept
    /// unordered pair emits both directed orientations.
    pub density: f64,
    /// Label-flip probability (applied once per unordered pair, so both
    /// orientations always agree).
    pub noise: f64,
    /// Feature range, as in [`CheckerboardConfig`].
    pub feature_range: f64,
    /// RNG seed.
    pub seed: u64,
}

impl Default for HomogeneousConfig {
    fn default() -> Self {
        HomogeneousConfig { vertices: 300, density: 0.25, noise: 0.2, feature_range: 100.0, seed: 0 }
    }
}

/// Default homogeneous checkerboard (300 vertices).
pub fn homogeneous(seed: u64) -> HomogeneousConfig {
    HomogeneousConfig { seed, ..Default::default() }
}

impl HomogeneousConfig {
    /// Generate the dataset: `start_features` and `end_features` are the
    /// *same* vertex features, and the edge list holds each sampled pair in
    /// both orientations with one shared (possibly noise-flipped) label.
    ///
    /// [`Dataset::zero_shot_split`](crate::data::Dataset::zero_shot_split)
    /// and [`Dataset::ninefold_cv`](crate::data::Dataset::ninefold_cv)
    /// detect the shared vertex set and use one vertex mask for both roles,
    /// so a pair's two orientations always land in the same fold — no
    /// mirrored-label leakage between train and test.
    pub fn generate(&self) -> Dataset {
        let v = self.vertices;
        let mut rng = Pcg32::seeded(self.seed);
        let feat: Vec<f64> = rng.uniform_vec(v, 0.0, self.feature_range);
        let per_vertex = (((v as f64) * self.density).round() as usize).min(v);

        let mut start_idx = Vec::new();
        let mut end_idx = Vec::new();
        let mut labels = Vec::new();
        for i in 0..v {
            for j in rng.sample_indices(v, per_vertex) {
                // keep each unordered pair once (emitted below in both
                // orientations); skip self-loops
                if j <= i {
                    continue;
                }
                let mut y = true_label(feat[i], feat[j]);
                if rng.bernoulli(self.noise) {
                    y = -y;
                }
                start_idx.push(i as u32);
                end_idx.push(j as u32);
                labels.push(y);
                start_idx.push(j as u32);
                end_idx.push(i as u32);
                labels.push(y);
            }
        }

        let features = Matrix::from_vec(v, 1, feat);
        Dataset {
            start_features: features.clone(),
            end_features: features,
            start_idx,
            end_idx,
            labels,
            name: format!("homo-{v}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_matches_config() {
        let cfg = CheckerboardConfig { m: 40, q: 50, density: 0.25, noise: 0.2, seed: 1, ..Default::default() };
        let ds = cfg.generate();
        ds.validate().unwrap();
        assert_eq!(ds.m(), 40);
        assert_eq!(ds.q(), 50);
        assert_eq!(ds.n_edges(), cfg.n_edges());
        assert_eq!(ds.n_edges(), 40 * 13); // round(50*0.25)=13 per row
    }

    #[test]
    fn paper_shapes() {
        assert_eq!(checker(0).n_edges(), 250_000);
        assert_eq!(checker_plus(0).n_edges(), 10_240_000);
    }

    #[test]
    fn noise_rate_is_approximately_correct() {
        let cfg = CheckerboardConfig { m: 100, q: 100, density: 0.5, noise: 0.2, seed: 2, ..Default::default() };
        let ds = cfg.generate();
        let flipped = ds
            .labels
            .iter()
            .enumerate()
            .filter(|(h, &y)| {
                let d = ds.start_features.get(ds.start_idx[*h] as usize, 0);
                let t = ds.end_features.get(ds.end_idx[*h] as usize, 0);
                y != true_label(d, t)
            })
            .count();
        let rate = flipped as f64 / ds.n_edges() as f64;
        assert!((rate - 0.2).abs() < 0.02, "flip rate={rate}");
    }

    #[test]
    fn no_duplicate_edges_within_row() {
        let cfg = CheckerboardConfig { m: 10, q: 30, density: 0.5, noise: 0.0, seed: 3, ..Default::default() };
        let ds = cfg.generate();
        for i in 0..10u32 {
            let mut ends: Vec<u32> = ds
                .start_idx
                .iter()
                .zip(&ds.end_idx)
                .filter(|(&s, _)| s == i)
                .map(|(_, &e)| e)
                .collect();
            let len = ends.len();
            ends.sort_unstable();
            ends.dedup();
            assert_eq!(ends.len(), len);
        }
    }

    #[test]
    fn class_balance_is_roughly_even() {
        let ds = CheckerboardConfig { m: 120, q: 120, density: 0.3, noise: 0.2, seed: 4, ..Default::default() }
            .generate();
        let st = ds.stats();
        let frac = st.positives as f64 / st.edges as f64;
        assert!((frac - 0.5).abs() < 0.06, "positive fraction={frac}");
    }

    #[test]
    fn homogeneous_graph_is_symmetric() {
        let ds = HomogeneousConfig { vertices: 40, density: 0.3, noise: 0.2, seed: 5, ..Default::default() }
            .generate();
        ds.validate().unwrap();
        assert!(ds.n_edges() > 0);
        // one shared vertex set on both sides
        assert_eq!(ds.start_features.data(), ds.end_features.data());
        // every edge's mirror exists and carries the identical label
        use std::collections::HashMap;
        let mut label_of: HashMap<(u32, u32), f64> = HashMap::new();
        for h in 0..ds.n_edges() {
            label_of.insert((ds.start_idx[h], ds.end_idx[h]), ds.labels[h]);
        }
        for h in 0..ds.n_edges() {
            let mirror = label_of
                .get(&(ds.end_idx[h], ds.start_idx[h]))
                .expect("mirror orientation present");
            assert_eq!(*mirror, ds.labels[h], "edge {h}");
            assert_ne!(ds.start_idx[h], ds.end_idx[h], "no self-loops");
        }
    }

    #[test]
    fn homogeneous_graph_is_deterministic() {
        let a = HomogeneousConfig { vertices: 30, density: 0.4, noise: 0.1, seed: 6, ..Default::default() }
            .generate();
        let b = HomogeneousConfig { vertices: 30, density: 0.4, noise: 0.1, seed: 6, ..Default::default() }
            .generate();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.start_idx, b.start_idx);
        assert_eq!(a.end_idx, b.end_idx);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = CheckerboardConfig { m: 20, q: 20, density: 0.4, noise: 0.1, seed: 9, ..Default::default() }.generate();
        let b = CheckerboardConfig { m: 20, q: 20, density: 0.4, noise: 0.1, seed: 9, ..Default::default() }.generate();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.start_idx, b.start_idx);
    }
}
