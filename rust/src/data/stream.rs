//! Streaming edge sources: chunked, re-readable access to labeled edges so
//! the stochastic trainer ([`crate::train::stochastic`]) never needs the
//! full label vector or edge index in one allocation.
//!
//! Two implementations of [`StreamingEdgeSource`] ship:
//!
//! * [`InMemorySource`] — an adapter over any existing [`Dataset`], slicing
//!   its edge arrays into fixed-size chunks;
//! * [`BinaryEdgeReader`] — an on-disk reader for the `kronvt-edges/v1`
//!   chunked binary format written by [`BinaryEdgeWriter`] (or the
//!   [`write_dataset_edges`] converter), seeking straight to a chunk
//!   without ever loading the whole edge set.
//!
//! Both sources chunk the *same* edge sequence identically for equal
//! `chunk_edges`, and every value round-trips bit-for-bit (indices as
//! little-endian `u32`, labels as little-endian `f64` bit patterns) — so a
//! seeded stochastic fit is **bitwise identical** whether it streams from
//! memory or from disk (pinned in `tests/stochastic.rs`).
//!
//! # `kronvt-edges/v1` on-disk layout
//!
//! ```text
//! magic   8 bytes   b"KVTEDGS1"
//! n       u64 LE    total edge count
//! chunk   u64 LE    nominal edges per chunk (≥ 1; last chunk may be short)
//! then, chunk-major, for each chunk of length L:
//!   L × u32 LE      start-vertex indices
//!   L × u32 LE      end-vertex indices
//!   L × f64 LE      labels (raw IEEE-754 bit patterns)
//! ```
//!
//! Every chunk except the last holds exactly `chunk` edges, so chunk `k`
//! starts at byte `24 + 16·k·chunk` — random access needs no chunk table.

use std::fs::File;
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use super::Dataset;

/// Default chunk granularity for streaming sources: large enough to
/// amortize per-chunk overhead (plans, bucketing), small enough that a
/// chunk's arrays stay a bounded allocation (~1 MiB) independent of the
/// total edge count.
pub const DEFAULT_CHUNK_EDGES: usize = 65_536;

/// Magic bytes opening a `kronvt-edges/v1` file.
const MAGIC: &[u8; 8] = b"KVTEDGS1";

/// Header length in bytes: magic + `n_edges` + `chunk_edges`.
const HEADER_LEN: u64 = 24;

/// Bytes per edge in the payload: two `u32` indices + one `f64` label.
const EDGE_BYTES: u64 = 16;

/// One contiguous run of labeled edges handed out by a
/// [`StreamingEdgeSource`]; arrays are index-aligned.
#[derive(Debug, Clone, PartialEq)]
pub struct EdgeChunk {
    /// Edge start-vertex indices (rows of the start-feature matrix).
    pub start_idx: Vec<u32>,
    /// Edge end-vertex indices (rows of the end-feature matrix).
    pub end_idx: Vec<u32>,
    /// Edge labels.
    pub labels: Vec<f64>,
}

impl EdgeChunk {
    /// Number of edges in the chunk.
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    /// Whether the chunk holds zero edges.
    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    /// Check index bounds against the vertex counts (`start < m`,
    /// `end < q`) and array alignment.
    pub fn validate(&self, m: usize, q: usize) -> Result<(), String> {
        if self.start_idx.len() != self.labels.len() || self.end_idx.len() != self.labels.len() {
            return Err("edge chunk arrays have mismatched lengths".into());
        }
        for (i, (&s, &e)) in self.start_idx.iter().zip(&self.end_idx).enumerate() {
            if s as usize >= m {
                return Err(format!("chunk edge {i}: start index {s} ≥ m={m}"));
            }
            if e as usize >= q {
                return Err(format!("chunk edge {i}: end index {e} ≥ q={q}"));
            }
        }
        Ok(())
    }
}

/// Chunked, re-readable access to a labeled edge sequence.
///
/// The contract the stochastic trainer relies on:
///
/// * chunks partition the edge sequence in order — chunk `k` covers global
///   edge positions [`StreamingEdgeSource::chunk_range`]`(k)`;
/// * every chunk except possibly the last holds exactly
///   [`StreamingEdgeSource::chunk_edges`] edges;
/// * [`StreamingEdgeSource::read_chunk`] is repeatable: reading the same
///   chunk twice (e.g. once per epoch) yields identical values.
pub trait StreamingEdgeSource {
    /// Total number of labeled edges.
    fn n_edges(&self) -> usize;

    /// Nominal edges per chunk (the last chunk may be shorter).
    fn chunk_edges(&self) -> usize;

    /// Number of chunks covering the edge sequence.
    fn n_chunks(&self) -> usize {
        self.n_edges().div_ceil(self.chunk_edges())
    }

    /// Global edge-position range `[lo, hi)` covered by chunk `k`.
    fn chunk_range(&self, k: usize) -> (usize, usize) {
        let lo = k * self.chunk_edges();
        (lo, (lo + self.chunk_edges()).min(self.n_edges()))
    }

    /// Read chunk `k` (`0 ≤ k <` [`StreamingEdgeSource::n_chunks`]).
    fn read_chunk(&self, k: usize) -> Result<EdgeChunk, String>;
}

/// [`StreamingEdgeSource`] adapter over an in-memory [`Dataset`]: chunks
/// are slices of the dataset's edge arrays, in edge order. With equal
/// `chunk_edges` it yields the same chunk stream as a
/// [`BinaryEdgeReader`] over a file converted from the same dataset.
#[derive(Debug, Clone)]
pub struct InMemorySource<'a> {
    data: &'a Dataset,
    chunk_edges: usize,
}

impl<'a> InMemorySource<'a> {
    /// Adapter with the [`DEFAULT_CHUNK_EDGES`] granularity.
    pub fn new(data: &'a Dataset) -> InMemorySource<'a> {
        InMemorySource { data, chunk_edges: DEFAULT_CHUNK_EDGES }
    }

    /// Adapter with an explicit chunk granularity (must be ≥ 1).
    pub fn with_chunk_edges(data: &'a Dataset, chunk_edges: usize) -> Result<Self, String> {
        if chunk_edges == 0 {
            return Err(
                "streaming source chunk_edges must be ≥ 1 (got 0); \
                 use InMemorySource::new for the default granularity"
                    .into(),
            );
        }
        Ok(InMemorySource { data, chunk_edges })
    }
}

impl StreamingEdgeSource for InMemorySource<'_> {
    fn n_edges(&self) -> usize {
        self.data.n_edges()
    }

    fn chunk_edges(&self) -> usize {
        self.chunk_edges
    }

    fn read_chunk(&self, k: usize) -> Result<EdgeChunk, String> {
        let (lo, hi) = self.chunk_range(k);
        if lo >= hi {
            return Err(format!("chunk {k} out of range ({} chunks)", self.n_chunks()));
        }
        Ok(EdgeChunk {
            start_idx: self.data.start_idx[lo..hi].to_vec(),
            end_idx: self.data.end_idx[lo..hi].to_vec(),
            labels: self.data.labels[lo..hi].to_vec(),
        })
    }
}

/// Incremental writer for the `kronvt-edges/v1` format: push edges one at a
/// time (buffering one chunk, never the full edge set) and call
/// [`BinaryEdgeWriter::finish`] to patch the header with the final count.
#[derive(Debug)]
pub struct BinaryEdgeWriter {
    out: BufWriter<File>,
    chunk_edges: usize,
    start_buf: Vec<u32>,
    end_buf: Vec<u32>,
    label_buf: Vec<f64>,
    written: u64,
}

impl BinaryEdgeWriter {
    /// Create (truncating) `path` with the given chunk granularity (≥ 1).
    pub fn create(path: &Path, chunk_edges: usize) -> Result<BinaryEdgeWriter, String> {
        if chunk_edges == 0 {
            return Err("edge-file chunk_edges must be ≥ 1 (got 0)".into());
        }
        let file = File::create(path)
            .map_err(|e| format!("failed to create edge file {}: {e}", path.display()))?;
        let mut out = BufWriter::new(file);
        // n_edges is patched by finish(); write 0 so a crashed conversion
        // reads back as an empty (not corrupt) edge set.
        out.write_all(MAGIC)
            .and_then(|()| out.write_all(&0u64.to_le_bytes()))
            .and_then(|()| out.write_all(&(chunk_edges as u64).to_le_bytes()))
            .map_err(|e| format!("failed to write edge-file header: {e}"))?;
        Ok(BinaryEdgeWriter {
            out,
            chunk_edges,
            start_buf: Vec::with_capacity(chunk_edges),
            end_buf: Vec::with_capacity(chunk_edges),
            label_buf: Vec::with_capacity(chunk_edges),
            written: 0,
        })
    }

    /// Append one labeled edge; flushes a full chunk to disk transparently.
    pub fn push(&mut self, start: u32, end: u32, label: f64) -> Result<(), String> {
        self.start_buf.push(start);
        self.end_buf.push(end);
        self.label_buf.push(label);
        if self.label_buf.len() == self.chunk_edges {
            self.flush_chunk()?;
        }
        Ok(())
    }

    /// Write the buffered chunk's three arrays in the chunk-major layout.
    fn flush_chunk(&mut self) -> Result<(), String> {
        for &s in &self.start_buf {
            self.out
                .write_all(&s.to_le_bytes())
                .map_err(|e| format!("failed to write edge chunk: {e}"))?;
        }
        for &t in &self.end_buf {
            self.out
                .write_all(&t.to_le_bytes())
                .map_err(|e| format!("failed to write edge chunk: {e}"))?;
        }
        for &y in &self.label_buf {
            self.out
                .write_all(&y.to_le_bytes())
                .map_err(|e| format!("failed to write edge chunk: {e}"))?;
        }
        self.written += self.label_buf.len() as u64;
        self.start_buf.clear();
        self.end_buf.clear();
        self.label_buf.clear();
        Ok(())
    }

    /// Flush the trailing partial chunk, patch the header's edge count, and
    /// sync the file. Returns the total edge count written.
    pub fn finish(mut self) -> Result<usize, String> {
        if !self.label_buf.is_empty() {
            self.flush_chunk()?;
        }
        self.out.flush().map_err(|e| format!("failed to flush edge file: {e}"))?;
        let file = self.out.get_mut();
        file.seek(SeekFrom::Start(MAGIC.len() as u64))
            .and_then(|_| file.write_all(&self.written.to_le_bytes()))
            .and_then(|()| file.sync_all())
            .map_err(|e| format!("failed to finalize edge-file header: {e}"))?;
        Ok(self.written as usize)
    }
}

/// Convert an in-memory [`Dataset`]'s edges to the `kronvt-edges/v1` format
/// at `path`. Returns the edge count written. A [`BinaryEdgeReader`] over
/// the result yields the same chunk stream as
/// [`InMemorySource::with_chunk_edges`] on the dataset with equal
/// `chunk_edges`.
pub fn write_dataset_edges(
    path: &Path,
    data: &Dataset,
    chunk_edges: usize,
) -> Result<usize, String> {
    let mut writer = BinaryEdgeWriter::create(path, chunk_edges)?;
    for i in 0..data.n_edges() {
        writer.push(data.start_idx[i], data.end_idx[i], data.labels[i])?;
    }
    writer.finish()
}

/// [`StreamingEdgeSource`] over a `kronvt-edges/v1` file: the header is
/// validated once at open (magic, chunk granularity, exact payload length);
/// each [`StreamingEdgeSource::read_chunk`] seeks straight to the chunk and
/// reads only its bytes.
#[derive(Debug, Clone)]
pub struct BinaryEdgeReader {
    path: PathBuf,
    n_edges: usize,
    chunk_edges: usize,
}

impl BinaryEdgeReader {
    /// Open and validate the header of a `kronvt-edges/v1` file.
    pub fn open(path: &Path) -> Result<BinaryEdgeReader, String> {
        let mut file = File::open(path)
            .map_err(|e| format!("failed to open edge file {}: {e}", path.display()))?;
        let mut header = [0u8; HEADER_LEN as usize];
        file.read_exact(&mut header)
            .map_err(|e| format!("failed to read edge-file header of {}: {e}", path.display()))?;
        if &header[..8] != MAGIC {
            return Err(format!(
                "{} is not a kronvt-edges/v1 file (bad magic)",
                path.display()
            ));
        }
        let n_edges = u64::from_le_bytes(header[8..16].try_into().expect("8 bytes"));
        let chunk_edges = u64::from_le_bytes(header[16..24].try_into().expect("8 bytes"));
        if chunk_edges == 0 {
            return Err(format!("{}: chunk_edges is 0 in header", path.display()));
        }
        let expected = HEADER_LEN + n_edges * EDGE_BYTES;
        let actual = file
            .metadata()
            .map_err(|e| format!("failed to stat {}: {e}", path.display()))?
            .len();
        if actual != expected {
            return Err(format!(
                "{}: truncated or oversized payload ({actual} bytes, expected {expected} for \
                 {n_edges} edges)",
                path.display()
            ));
        }
        Ok(BinaryEdgeReader {
            path: path.to_path_buf(),
            n_edges: n_edges as usize,
            chunk_edges: chunk_edges as usize,
        })
    }

    /// The file this reader streams from.
    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl StreamingEdgeSource for BinaryEdgeReader {
    fn n_edges(&self) -> usize {
        self.n_edges
    }

    fn chunk_edges(&self) -> usize {
        self.chunk_edges
    }

    fn read_chunk(&self, k: usize) -> Result<EdgeChunk, String> {
        let (lo, hi) = self.chunk_range(k);
        if lo >= hi {
            return Err(format!("chunk {k} out of range ({} chunks)", self.n_chunks()));
        }
        let len = hi - lo;
        let mut file = File::open(&self.path)
            .map_err(|e| format!("failed to open edge file {}: {e}", self.path.display()))?;
        file.seek(SeekFrom::Start(HEADER_LEN + lo as u64 * EDGE_BYTES))
            .map_err(|e| format!("failed to seek edge file {}: {e}", self.path.display()))?;
        let mut bytes = vec![0u8; len * EDGE_BYTES as usize];
        file.read_exact(&mut bytes)
            .map_err(|e| format!("failed to read chunk {k} of {}: {e}", self.path.display()))?;
        let (starts, rest) = bytes.split_at(len * 4);
        let (ends, labels) = rest.split_at(len * 4);
        Ok(EdgeChunk {
            start_idx: starts
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes(b.try_into().expect("4 bytes")))
                .collect(),
            end_idx: ends
                .chunks_exact(4)
                .map(|b| u32::from_le_bytes(b.try_into().expect("4 bytes")))
                .collect(),
            labels: labels
                .chunks_exact(8)
                .map(|b| f64::from_le_bytes(b.try_into().expect("8 bytes")))
                .collect(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::checkerboard::CheckerboardConfig;

    fn temp_path(tag: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("kronvt-stream-{tag}-{}.edges", std::process::id()));
        p
    }

    #[test]
    fn in_memory_chunks_cover_the_dataset() {
        let ds = CheckerboardConfig { m: 12, q: 10, ..CheckerboardConfig::default() }.generate();
        let src = InMemorySource::with_chunk_edges(&ds, 17).unwrap();
        assert_eq!(src.n_edges(), ds.n_edges());
        let mut seen = 0;
        for k in 0..src.n_chunks() {
            let (lo, hi) = src.chunk_range(k);
            let chunk = src.read_chunk(k).unwrap();
            assert_eq!(chunk.len(), hi - lo);
            assert!(chunk.validate(ds.m(), ds.q()).is_ok());
            assert_eq!(chunk.labels, &ds.labels[lo..hi]);
            assert_eq!(chunk.start_idx, &ds.start_idx[lo..hi]);
            assert_eq!(chunk.end_idx, &ds.end_idx[lo..hi]);
            seen += chunk.len();
        }
        assert_eq!(seen, ds.n_edges());
        assert!(InMemorySource::with_chunk_edges(&ds, 0).is_err());
    }

    #[test]
    fn binary_round_trip_is_bitwise() {
        let mut ds =
            CheckerboardConfig { m: 9, q: 11, ..CheckerboardConfig::default() }.generate();
        // exotic bit patterns must survive the trip untouched
        ds.labels[0] = -0.0;
        ds.labels[1] = f64::MIN_POSITIVE / 2.0; // subnormal
        let path = temp_path("roundtrip");
        let written = write_dataset_edges(&path, &ds, 13).unwrap();
        assert_eq!(written, ds.n_edges());
        let reader = BinaryEdgeReader::open(&path).unwrap();
        assert_eq!(reader.n_edges(), ds.n_edges());
        assert_eq!(reader.chunk_edges(), 13);
        let mem = InMemorySource::with_chunk_edges(&ds, 13).unwrap();
        assert_eq!(reader.n_chunks(), mem.n_chunks());
        for k in 0..reader.n_chunks() {
            let a = reader.read_chunk(k).unwrap();
            let b = mem.read_chunk(k).unwrap();
            assert_eq!(a.start_idx, b.start_idx, "chunk {k}");
            assert_eq!(a.end_idx, b.end_idx, "chunk {k}");
            let bits_a: Vec<u64> = a.labels.iter().map(|y| y.to_bits()).collect();
            let bits_b: Vec<u64> = b.labels.iter().map(|y| y.to_bits()).collect();
            assert_eq!(bits_a, bits_b, "chunk {k}");
        }
        // re-reading a chunk yields identical values
        assert_eq!(reader.read_chunk(0).unwrap(), reader.read_chunk(0).unwrap());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn reader_rejects_corrupt_files() {
        let ds = CheckerboardConfig { m: 6, q: 6, ..CheckerboardConfig::default() }.generate();
        let path = temp_path("corrupt");
        write_dataset_edges(&path, &ds, 8).unwrap();
        // bad magic
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[0] = b'X';
        std::fs::write(&path, &bytes).unwrap();
        assert!(BinaryEdgeReader::open(&path).unwrap_err().contains("bad magic"));
        // truncated payload
        bytes[0] = b'K';
        bytes.pop();
        std::fs::write(&path, &bytes).unwrap();
        assert!(BinaryEdgeReader::open(&path).unwrap_err().contains("truncated"));
        std::fs::remove_file(&path).ok();
    }
}
