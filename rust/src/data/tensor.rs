//! Grid / tensor datasets for D-way tensor-product chains.
//!
//! A [`TensorDataset`] is the D-mode analogue of [`Dataset`]: one vertex
//! feature matrix **per mode** and a [`TensorIndex`] mapping each labeled
//! cell to its per-mode vertex tuple. The two-factor container stays the
//! primary pairwise-learning type; this one feeds the tensor-chain
//! estimators ([`TensorKernelOp`](crate::gvt::TensorKernelOp) and the
//! `Learner` grid path).
//!
//! [`GridCheckerboardConfig`] generates the **spatio-temporal checkerboard**
//! — the D-way generalization of the paper's §5.1 Checkerboard simulation:
//! every mode carries a single uniform feature in `(0, feature_range)`, the
//! noise-free label of a cell is `+1` when `Σ_d ⌊x_d⌋` is even and `−1`
//! otherwise (for `D = 2` this is exactly the classic checkerboard truth),
//! labels flip with probability `noise`, and a fraction `density` of the
//! `Π_d dims[d]` grid cells is labeled.

use super::dataset::Dataset;
use crate::gvt::TensorIndex;
use crate::linalg::Matrix;
use crate::util::rng::Pcg32;

/// A labeled set of cells on a D-way vertex grid, with one feature matrix
/// per mode.
#[derive(Debug, Clone)]
pub struct TensorDataset {
    /// One vertex feature matrix per mode; `features[d]` has one row per
    /// mode-`d` vertex.
    pub features: Vec<Matrix>,
    /// Per-mode vertex columns of the labeled cells (one entry per edge).
    pub index: TensorIndex,
    /// Labels `y_h ∈ {−1, +1}` (regression targets also allowed).
    pub labels: Vec<f64>,
    /// Dataset name (reporting).
    pub name: String,
}

impl TensorDataset {
    /// Number of modes `D`.
    pub fn order(&self) -> usize {
        self.features.len()
    }

    /// Number of labeled cells (edges).
    pub fn n_edges(&self) -> usize {
        self.index.len()
    }

    /// Per-mode vertex counts `(d₁, …, d_D)`.
    pub fn dims(&self) -> Vec<usize> {
        self.features.iter().map(|f| f.rows()).collect()
    }

    /// Structural validation: at least two modes, index/label/feature
    /// consistency, every index in bounds.
    pub fn validate(&self) -> Result<(), String> {
        if self.features.len() < 2 {
            return Err(format!(
                "tensor dataset needs at least two modes, got {}",
                self.features.len()
            ));
        }
        if self.features.len() != self.index.order() {
            return Err(format!(
                "{} feature matrices but the index has {} modes",
                self.features.len(),
                self.index.order()
            ));
        }
        if self.labels.len() != self.index.len() {
            return Err(format!(
                "{} labels but {} indexed cells",
                self.labels.len(),
                self.index.len()
            ));
        }
        self.index.validate(&self.dims())
    }

    /// Whether the labeled cells enumerate the **complete grid** (every cell
    /// exactly once) — the condition under which closed-form grid methods
    /// apply; see [`TensorIndex::complete_layout`].
    pub fn is_complete_grid(&self) -> bool {
        self.index.complete_layout(&self.dims()).is_some()
    }

    /// Restrict to the cells at `edge_pos` (in that order), sharing the
    /// per-mode feature matrices.
    pub fn subset_by_edges(&self, edge_pos: &[usize], name: &str) -> TensorDataset {
        TensorDataset {
            features: self.features.clone(),
            index: TensorIndex::new(
                self.index
                    .modes
                    .iter()
                    .map(|col| edge_pos.iter().map(|&h| col[h]).collect())
                    .collect(),
            ),
            labels: edge_pos.iter().map(|&h| self.labels[h]).collect(),
            name: name.into(),
        }
    }

    /// Random cell-level holdout split: `test_frac` of the labeled cells go
    /// to the test set, the rest to training. Both halves share the vertex
    /// feature matrices (grid prediction interpolates over the same
    /// vertices, unlike the two-factor zero-shot protocol).
    pub fn holdout_split(&self, test_frac: f64, seed: u64) -> (TensorDataset, TensorDataset) {
        assert!((0.0..1.0).contains(&test_frac), "test_frac must be in [0, 1)");
        let n = self.n_edges();
        let mut order: Vec<usize> = (0..n).collect();
        Pcg32::seeded(seed).shuffle(&mut order);
        let n_test = ((n as f64) * test_frac).round() as usize;
        let (test_pos, train_pos) = order.split_at(n_test);
        let mut train_pos = train_pos.to_vec();
        let mut test_pos = test_pos.to_vec();
        // deterministic edge order within each half
        train_pos.sort_unstable();
        test_pos.sort_unstable();
        (
            self.subset_by_edges(&train_pos, &format!("{}-train", self.name)),
            self.subset_by_edges(&test_pos, &format!("{}-test", self.name)),
        )
    }

    /// View a two-factor [`Dataset`] as a `D = 2` tensor dataset
    /// (mode 0 = end vertices, mode 1 = start vertices — the `G ⊗ K` row
    /// ordering used everywhere in the crate).
    pub fn from_dataset(ds: &Dataset) -> TensorDataset {
        TensorDataset {
            features: vec![ds.end_features.clone(), ds.start_features.clone()],
            index: TensorIndex::from_kron(&ds.kron_index()),
            labels: ds.labels.clone(),
            name: ds.name.clone(),
        }
    }
}

/// Noise-free spatio-temporal checkerboard label for one per-mode feature
/// tuple: `+1` iff `Σ_d ⌊x_d⌋` is even. For two modes this is exactly
/// [`true_label`](super::checkerboard::true_label).
pub fn true_grid_label(coords: &[f64]) -> f64 {
    let parity: i64 = coords.iter().map(|&x| x.floor() as i64).sum();
    if parity % 2 == 0 {
        1.0
    } else {
        -1.0
    }
}

/// Configuration for the D-way spatio-temporal checkerboard generator.
#[derive(Debug, Clone)]
pub struct GridCheckerboardConfig {
    /// Vertex count per mode (`dims.len()` = the chain order `D ≥ 2`).
    pub dims: Vec<usize>,
    /// Fraction of the `Π_d dims[d]` grid cells that receive labels.
    pub density: f64,
    /// Label-flip probability.
    pub noise: f64,
    /// Features are uniform in `(0, feature_range)` per mode.
    pub feature_range: f64,
    /// RNG seed (features, cell sampling, label noise).
    pub seed: u64,
}

impl Default for GridCheckerboardConfig {
    fn default() -> Self {
        GridCheckerboardConfig {
            dims: vec![30, 30, 30],
            density: 0.25,
            noise: 0.2,
            feature_range: 8.0,
            seed: 0,
        }
    }
}

impl GridCheckerboardConfig {
    /// Generate the dataset: one uniform 1-d feature per mode vertex, then a
    /// density-sampled subset of grid cells labeled by floor-parity truth
    /// with noise flips. Deterministic given the seed.
    pub fn generate(&self) -> TensorDataset {
        assert!(self.dims.len() >= 2, "grid checkerboard needs at least two modes");
        assert!(self.dims.iter().all(|&d| d > 0), "every mode needs at least one vertex");
        let total: usize = self
            .dims
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .unwrap_or_else(|| panic!("grid size {:?} overflows usize", self.dims));
        let mut rng = Pcg32::seeded(self.seed);
        let feats: Vec<Vec<f64>> = self
            .dims
            .iter()
            .map(|&d| rng.uniform_vec(d, 0.0, self.feature_range))
            .collect();

        let mut modes: Vec<Vec<u32>> = vec![Vec::new(); self.dims.len()];
        let mut labels = Vec::new();
        // walk the full grid once; keep each cell with probability `density`
        let mut coords = vec![0usize; self.dims.len()];
        for _ in 0..total {
            if rng.bernoulli(self.density) {
                let point: Vec<f64> = coords.iter().zip(&feats).map(|(&i, f)| f[i]).collect();
                let mut y = true_grid_label(&point);
                if rng.bernoulli(self.noise) {
                    y = -y;
                }
                for (col, &i) in modes.iter_mut().zip(&coords) {
                    col.push(i as u32);
                }
                labels.push(y);
            }
            // row-major increment (last mode fastest)
            for d in (0..coords.len()).rev() {
                coords[d] += 1;
                if coords[d] < self.dims[d] {
                    break;
                }
                coords[d] = 0;
            }
        }

        TensorDataset {
            features: self
                .dims
                .iter()
                .zip(feats)
                .map(|(&d, f)| Matrix::from_vec(d, 1, f))
                .collect(),
            index: TensorIndex::new(modes),
            labels,
            name: format!(
                "grid-checker-{}",
                self.dims.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
            ),
        }
    }

    /// Generate the **complete** grid (density 1, every cell labeled once,
    /// row-major order) — the workload for complete-grid fast paths and the
    /// dense-oracle tests.
    pub fn generate_complete(&self) -> TensorDataset {
        let mut cfg = self.clone();
        cfg.density = 1.0;
        let ds = cfg.generate();
        debug_assert!(ds.is_complete_grid());
        ds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_shape_and_determinism() {
        let cfg = GridCheckerboardConfig {
            dims: vec![6, 5, 4],
            density: 0.5,
            noise: 0.1,
            feature_range: 4.0,
            seed: 11,
        };
        let a = cfg.generate();
        a.validate().unwrap();
        assert_eq!(a.order(), 3);
        assert_eq!(a.dims(), vec![6, 5, 4]);
        // density-sampled: roughly half the 120 cells
        assert!(a.n_edges() > 30 && a.n_edges() < 90, "n={}", a.n_edges());
        let b = cfg.generate();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.index, b.index);
    }

    #[test]
    fn labels_follow_floor_parity_up_to_noise() {
        let cfg = GridCheckerboardConfig {
            dims: vec![10, 10, 10],
            density: 0.4,
            noise: 0.0,
            feature_range: 5.0,
            seed: 12,
        };
        let ds = cfg.generate();
        for h in 0..ds.n_edges() {
            let point: Vec<f64> = ds
                .features
                .iter()
                .zip(&ds.index.modes)
                .map(|(f, col)| f.get(col[h] as usize, 0))
                .collect();
            assert_eq!(ds.labels[h], true_grid_label(&point), "cell {h}");
        }
    }

    #[test]
    fn two_mode_truth_matches_classic_checkerboard() {
        use super::super::checkerboard::true_label;
        for (d, t) in [(0.4, 1.7), (3.2, 2.9), (5.5, 5.5), (0.0, 1.0)] {
            assert_eq!(true_grid_label(&[d, t]), true_label(d, t));
        }
    }

    #[test]
    fn complete_grid_generation_and_detection() {
        let cfg = GridCheckerboardConfig {
            dims: vec![3, 4, 2],
            density: 0.3,
            noise: 0.0,
            feature_range: 4.0,
            seed: 13,
        };
        let full = cfg.generate_complete();
        assert_eq!(full.n_edges(), 24);
        assert!(full.is_complete_grid());
        let sparse = cfg.generate();
        assert!(sparse.n_edges() < 24);
        assert!(!sparse.is_complete_grid());
    }

    #[test]
    fn holdout_split_partitions_cells() {
        let ds = GridCheckerboardConfig {
            dims: vec![8, 7, 6],
            density: 0.5,
            noise: 0.1,
            feature_range: 4.0,
            seed: 14,
        }
        .generate();
        let n = ds.n_edges();
        let (train, test) = ds.holdout_split(0.25, 3);
        train.validate().unwrap();
        test.validate().unwrap();
        assert_eq!(train.n_edges() + test.n_edges(), n);
        assert_eq!(test.n_edges(), ((n as f64) * 0.25).round() as usize);
        // both halves share the feature matrices
        for d in 0..ds.order() {
            assert_eq!(train.features[d].data(), ds.features[d].data());
            assert_eq!(test.features[d].data(), ds.features[d].data());
        }
    }

    #[test]
    fn from_dataset_embeds_two_factor_data() {
        let ds = super::super::checkerboard::CheckerboardConfig {
            m: 10,
            q: 8,
            density: 0.4,
            noise: 0.1,
            feature_range: 4.0,
            seed: 15,
        }
        .generate();
        let t = TensorDataset::from_dataset(&ds);
        t.validate().unwrap();
        assert_eq!(t.order(), 2);
        assert_eq!(t.dims(), vec![ds.q(), ds.m()]);
        assert_eq!(t.labels, ds.labels);
        assert_eq!(t.index.to_kron(), Some(ds.kron_index()));
    }

    #[test]
    fn validate_rejects_malformed_datasets() {
        let good = GridCheckerboardConfig {
            dims: vec![4, 4],
            density: 0.5,
            noise: 0.0,
            feature_range: 4.0,
            seed: 16,
        }
        .generate();
        let mut short_labels = good.clone();
        short_labels.labels.pop();
        assert!(short_labels.validate().is_err());
        let mut one_mode = good.clone();
        one_mode.features.truncate(1);
        assert!(one_mode.validate().is_err());
        let mut oob = good.clone();
        oob.index.modes[0][0] = 99;
        assert!(oob.validate().is_err());
    }
}
