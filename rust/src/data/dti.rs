//! Synthetic drug–target interaction (DTI) data.
//!
//! The paper evaluates on four DTI datasets (Ki [60]; GPCR, IC, E [59])
//! whose chemical/genomic feature files are not redistributable. This module
//! generates *shape-exact* synthetic substitutes (same vertex counts, edge
//! counts and positive rates as Table 5) from a planted model:
//!
//! ```text
//! score(i,j) = ⟨u_i, v_j⟩ + β·(b_i + c_j) + ε
//! ```
//!
//! with latent factors `u, v`, vertex-level "promiscuity" biases `b, c`, and
//! observed features that are noisy linear views of the latents. The
//! bilinear term is exactly the structure a Kronecker product kernel can
//! represent while a linear model on concatenated features `[d, t]` cannot;
//! the bias term gives linear baselines partial signal — reproducing the
//! qualitative Table-6 ordering (Kron methods > SGD ≥ KNN) without the
//! original data. Labels are +1 for the top `positives` scores among the
//! sampled edges (exact class counts), with a small flip rate for realism.

use super::dataset::Dataset;
use crate::linalg::Matrix;
use crate::util::rng::Pcg32;

/// Configuration for synthetic DTI generation.
#[derive(Debug, Clone, Copy)]
pub struct DtiConfig {
    /// Number of start vertices (drugs), `m`.
    pub m: usize,
    /// Number of end vertices (targets), `q`.
    pub q: usize,
    /// Number of labeled edges, `n`.
    pub n: usize,
    /// Number of positive edges.
    pub positives: usize,
    /// Observed start-vertex feature dimension `d`.
    pub d_features: usize,
    /// Observed end-vertex feature dimension `r`.
    pub r_features: usize,
    /// Latent dimension of the planted bilinear model.
    pub latent: usize,
    /// Weight of the vertex-bias (linearly learnable) component.
    pub bias_weight: f64,
    /// Observation noise on features.
    pub feature_noise: f64,
    /// Label flip probability.
    pub flip: f64,
    /// RNG seed (latents, features, edge sampling, label noise).
    pub seed: u64,
}

impl Default for DtiConfig {
    fn default() -> Self {
        DtiConfig {
            m: 200,
            q: 100,
            n: 5000,
            positives: 250,
            d_features: 32,
            r_features: 32,
            latent: 8,
            bias_weight: 0.7,
            feature_noise: 0.3,
            flip: 0.05,
            seed: 0,
        }
    }
}

/// Shape-exact synthetic `Ki` ([60]: 1421 drugs × 156 targets, 93 356 edges,
/// 3 200 positive).
pub fn ki(seed: u64) -> DtiConfig {
    DtiConfig { m: 1421, q: 156, n: 93_356, positives: 3200, seed, ..Default::default() }
}

/// Shape-exact synthetic `GPCR` ([59]: 223×95, 5 296 edges, 165 positive).
pub fn gpcr(seed: u64) -> DtiConfig {
    DtiConfig { m: 223, q: 95, n: 5296, positives: 165, seed, ..Default::default() }
}

/// Shape-exact synthetic `IC` ([59]: 210×204, 10 710 edges, 369 positive).
pub fn ic(seed: u64) -> DtiConfig {
    DtiConfig { m: 210, q: 204, n: 10_710, positives: 369, seed, ..Default::default() }
}

/// Shape-exact synthetic `E` ([59]: 445×664, 73 870 edges, 732 positive).
pub fn e(seed: u64) -> DtiConfig {
    DtiConfig { m: 445, q: 664, n: 73_870, positives: 732, seed, ..Default::default() }
}

/// All four Table-5 DTI datasets as `(name, config)` pairs.
pub fn table5_datasets(seed: u64) -> Vec<(&'static str, DtiConfig)> {
    vec![("Ki", ki(seed)), ("GPCR", gpcr(seed)), ("IC", ic(seed)), ("E", e(seed))]
}

impl DtiConfig {
    /// Generate the dataset.
    pub fn generate(&self) -> Dataset {
        assert!(self.n <= self.m * self.q, "cannot sample more edges than pairs");
        assert!(self.positives <= self.n);
        let mut rng = Pcg32::seeded(self.seed ^ 0xD71);

        // Planted latents and biases.
        let u = Matrix::from_fn(self.m, self.latent, |_, _| rng.normal());
        let v = Matrix::from_fn(self.q, self.latent, |_, _| rng.normal());
        let b: Vec<f64> = rng.normal_vec(self.m);
        let c: Vec<f64> = rng.normal_vec(self.q);

        // Observed features = latents (+ bias as an extra visible coordinate)
        // mixed through a random linear map, plus noise. The bias is made
        // visible so linear baselines have something to learn. Maps are
        // scaled so observed features (and hence linear-kernel entries) stay
        // O(1) — real chemical/genomic similarity features are normalized
        // too, and λ grids are only meaningful on a normalized kernel scale.
        let d_scale = 1.0 / (((self.latent + 1) * self.d_features) as f64).sqrt();
        let r_scale = 1.0 / (((self.latent + 1) * self.r_features) as f64).sqrt();
        let d_map = Matrix::from_fn(self.latent + 1, self.d_features, |_, _| rng.normal() * d_scale);
        let r_map = Matrix::from_fn(self.latent + 1, self.r_features, |_, _| rng.normal() * r_scale);
        let mut start_features = Matrix::zeros(self.m, self.d_features);
        for i in 0..self.m {
            let mut lat: Vec<f64> = u.row(i).to_vec();
            lat.push(b[i]);
            for jf in 0..self.d_features {
                let mut acc = 0.0;
                for (l, &lv) in lat.iter().enumerate() {
                    acc += lv * d_map.get(l, jf);
                }
                let noise = self.feature_noise / (self.d_features as f64).sqrt();
                start_features.set(i, jf, acc + noise * rng.normal());
            }
        }
        let mut end_features = Matrix::zeros(self.q, self.r_features);
        for j in 0..self.q {
            let mut lat: Vec<f64> = v.row(j).to_vec();
            lat.push(c[j]);
            for jf in 0..self.r_features {
                let mut acc = 0.0;
                for (l, &lv) in lat.iter().enumerate() {
                    acc += lv * r_map.get(l, jf);
                }
                let noise = self.feature_noise / (self.r_features as f64).sqrt();
                end_features.set(j, jf, acc + noise * rng.normal());
            }
        }

        // Sample exactly n edges, spread row-wise (each drug is tested
        // against a subset of targets, as in real interaction panels).
        let base = self.n / self.m;
        let rem = self.n % self.m;
        let mut start_idx = Vec::with_capacity(self.n);
        let mut end_idx = Vec::with_capacity(self.n);
        let mut scores = Vec::with_capacity(self.n);
        for i in 0..self.m {
            let count = base + usize::from(i < rem);
            for j in rng.sample_indices(self.q, count.min(self.q)) {
                start_idx.push(i as u32);
                end_idx.push(j as u32);
                let mut s = crate::linalg::vecops::dot(u.row(i), v.row(j));
                s += self.bias_weight * (b[i] + c[j]);
                s += 0.1 * rng.normal();
                // A NaN/∞ affinity (e.g. a NaN `bias_weight` or
                // `feature_noise` in the config) would silently scramble the
                // order statistic below; reject it with a clear error.
                assert!(
                    s.is_finite(),
                    "non-finite affinity {s} for edge ({i},{j}) — check DtiConfig \
                     (bias_weight={}, feature_noise={}, flip={})",
                    self.bias_weight,
                    self.feature_noise,
                    self.flip
                );
                scores.push(s);
            }
        }
        let n_actual = scores.len();

        // Threshold at the (n - positives)-th order statistic → exact
        // counts. total_cmp: a total order, so sorting can never panic.
        // With no positives requested (or no edges) every label is negative.
        let mut sorted = scores.clone();
        sorted.sort_by(f64::total_cmp);
        let thresh = if self.positives == 0 || n_actual == 0 {
            f64::INFINITY
        } else {
            sorted[n_actual - self.positives.min(n_actual)]
        };
        let mut labels: Vec<f64> = scores
            .iter()
            .map(|&s| if s >= thresh { 1.0 } else { -1.0 })
            .collect();
        // Count-preserving label noise: swap the labels of `k` random
        // positive and `k` random negative edges, so the Table-5 class
        // counts stay exact while ~flip of the positives become noise.
        let pos_idx: Vec<usize> = (0..n_actual).filter(|&h| labels[h] > 0.0).collect();
        let neg_idx: Vec<usize> = (0..n_actual).filter(|&h| labels[h] < 0.0).collect();
        let k = ((self.flip * pos_idx.len() as f64).round() as usize)
            .min(pos_idx.len())
            .min(neg_idx.len());
        if k > 0 {
            for &pi in rng.sample_indices(pos_idx.len(), k).iter() {
                labels[pos_idx[pi]] = -1.0;
            }
            for &ni in rng.sample_indices(neg_idx.len(), k).iter() {
                labels[neg_idx[ni]] = 1.0;
            }
        }

        Dataset {
            start_features,
            end_features,
            start_idx,
            end_idx,
            labels,
            name: format!("dti-{}x{}", self.m, self.q),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_match_table5() {
        for (name, cfg) in table5_datasets(1) {
            // generation itself is tested on the small sets; Ki is big, so
            // just check config numbers here.
            match name {
                "Ki" => {
                    assert_eq!((cfg.m, cfg.q, cfg.n, cfg.positives), (1421, 156, 93_356, 3200))
                }
                "GPCR" => assert_eq!((cfg.m, cfg.q, cfg.n, cfg.positives), (223, 95, 5296, 165)),
                "IC" => assert_eq!((cfg.m, cfg.q, cfg.n, cfg.positives), (210, 204, 10_710, 369)),
                "E" => assert_eq!((cfg.m, cfg.q, cfg.n, cfg.positives), (445, 664, 73_870, 732)),
                _ => unreachable!(),
            }
        }
    }

    #[test]
    fn gpcr_generation_is_shape_exact() {
        let ds = gpcr(3).generate();
        ds.validate().unwrap();
        let st = ds.stats();
        assert_eq!(st.edges, 5296);
        assert_eq!(st.start_vertices, 223);
        assert_eq!(st.end_vertices, 95);
        // label noise is count-preserving → exact Table-5 positives
        assert_eq!(st.positives, 165);
    }

    #[test]
    fn imbalance_is_preserved() {
        let ds = ic(5).generate();
        let st = ds.stats();
        let rate = st.positives as f64 / st.edges as f64;
        assert!(rate < 0.12, "positive rate={rate}"); // IC is ~3.4% positive
    }

    #[test]
    #[should_panic(expected = "non-finite affinity")]
    fn nan_affinity_is_rejected_with_clear_error() {
        // regression: a NaN bias_weight used to surface as an opaque
        // `partial_cmp(b).unwrap()` panic deep inside the sort
        let cfg = DtiConfig {
            m: 5,
            q: 5,
            n: 10,
            positives: 3,
            bias_weight: f64::NAN,
            ..Default::default()
        };
        let _ = cfg.generate();
    }

    #[test]
    fn deterministic() {
        let a = gpcr(7).generate();
        let b = gpcr(7).generate();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.start_idx, b.start_idx);
        assert_eq!(a.start_features.data(), b.start_features.data());
    }

    #[test]
    fn signal_is_learnable_from_features() {
        // Sanity: a simple nearest-centroid on the *product* structure should
        // beat chance. We check that edges sharing a positive-heavy drug
        // correlate — weak proxy executed cheaply: positive edges should have
        // higher planted-score reconstruction from features. Instead of
        // re-deriving latents, check label autocorrelation per drug.
        let ds = gpcr(11).generate();
        let mut per_drug_pos = vec![0usize; ds.m()];
        let mut per_drug_tot = vec![0usize; ds.m()];
        for h in 0..ds.n_edges() {
            per_drug_tot[ds.start_idx[h] as usize] += 1;
            if ds.labels[h] > 0.0 {
                per_drug_pos[ds.start_idx[h] as usize] += 1;
            }
        }
        // Positives cluster on few drugs (bias term) → max per-drug positive
        // rate far above the global rate.
        let global = ds.stats().positives as f64 / ds.n_edges() as f64;
        let max_rate = per_drug_pos
            .iter()
            .zip(&per_drug_tot)
            .filter(|(_, &t)| t >= 5)
            .map(|(&p, &t)| p as f64 / t as f64)
            .fold(0.0, f64::max);
        assert!(max_rate > 3.0 * global, "max={max_rate}, global={global}");
    }
}
