//! The **unified estimator API**: one builder-based model lifecycle —
//! **fit → save → load → serve** — over every training scheme in the crate.
//!
//! The paper's framework is deliberately general: a single optimization
//! scheme (Algorithm 2 over the generalized vec trick) instantiated for
//! ridge, SVM, and arbitrary pairwise-kernel families. This module gives
//! that generality one public shape:
//!
//! * [`Compute`] — the execution policy (threads, workspace-pool retention,
//!   kernel-row cache sizing), the **single** source of these knobs.
//!   `RidgeConfig`/`SvmConfig`/`NewtonConfig`/`ServerConfig` no longer carry
//!   their own copies; trainers and the server consume one `Compute` by
//!   reference. Every knob is transparent to results.
//! * [`Estimator`] + [`Learner`] — the uniform trainer interface and its
//!   fluent builder:
//!
//!   ```
//!   # use kronvt::api::{Compute, Learner};
//!   # use kronvt::gvt::PairwiseKernelKind;
//!   # use kronvt::data::checkerboard::HomogeneousConfig;
//!   # // Symmetric pairwise kernels need a homogeneous graph: both edge
//!   # // roles index one shared vertex set.
//!   # let data = HomogeneousConfig { vertices: 60, density: 0.25, noise: 0.2, feature_range: 100.0, seed: 1 }.generate();
//!   let model = Learner::ridge()
//!       .lambda(1e-2)
//!       .iterations(50)
//!       .pairwise(PairwiseKernelKind::SymmetricKron)
//!       .compute(Compute::threads(2))
//!       .fit(&data)?;
//!   # assert!(model.as_dual().is_some());
//!   # Ok::<(), String>(())
//!   ```
//!
//!   covering Kronecker ridge (single-λ and the batched
//!   [`Learner::fit_path`]), the L2-SVM, and the generic truncated-Newton /
//!   primal paths.
//! * [`TrainedModel`] — the unified trained artifact wrapping
//!   [`DualModel`](crate::model::DualModel) /
//!   [`PrimalModel`](crate::model::PrimalModel), exposing `predict`,
//!   `predict_batch`, `into_context()` (serving), and the **versioned
//!   portable model artifact**: [`TrainedModel::save`] /
//!   [`TrainedModel::load`] write and read a `kronvt-model/v1` JSON document
//!   whose exact float encoding makes loaded models predict **bitwise
//!   identically** — train once, serve anywhere, no in-process handoff
//!   required.

mod artifact;
mod compute;
mod learner;
mod trained;

pub use artifact::{
    from_json as artifact_from_json, to_json as artifact_to_json, FORMAT, FORMAT_V2,
};
pub use compute::Compute;
pub use learner::{Estimator, Learner, NewtonLoss};
pub use trained::TrainedModel;
