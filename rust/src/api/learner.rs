//! The fluent [`Learner`] builder and the [`Estimator`] trait — one
//! training entry point for every model family in the crate.
//!
//! ```
//! use kronvt::api::{Compute, Learner};
//! use kronvt::data::checkerboard::CheckerboardConfig;
//! use kronvt::gvt::PairwiseKernelKind;
//! # let data = CheckerboardConfig { m: 40, q: 40, density: 0.25, noise: 0.2, feature_range: 8.0, seed: 1 }.generate();
//! let model = Learner::ridge()
//!     .lambda(1e-2)
//!     .iterations(50)
//!     .pairwise(PairwiseKernelKind::Kronecker)
//!     .compute(Compute::threads(2))
//!     .fit(&data)
//!     .unwrap();
//! assert_eq!(model.predict(&data).len(), data.n_edges());
//! ```

use super::{Compute, TrainedModel};
use crate::data::{Dataset, TensorDataset};
use crate::gvt::PairwiseKernelKind;
use crate::kernels::KernelKind;
use crate::losses::{L2SvmLoss, LogisticLoss, RankRlsLoss, RidgeLoss};
use crate::train::{
    fit_stochastic, KronRidge, KronSvm, NewtonConfig, NewtonTrainer, RidgeConfig, RidgeSolver,
    SamplingMode, StepPolicy, StochasticConfig, SvmConfig, TensorRidge, TensorRidgeConfig,
};

/// Anything that trains a [`TrainedModel`] from a [`Dataset`] — the uniform
/// estimator interface of the unified API. [`Learner`] is the crate's
/// implementation; downstream code can implement it for custom trainers and
/// reuse the same fit → save → load → serve lifecycle.
pub trait Estimator {
    /// Train a model on `data`.
    fn fit(&self, data: &Dataset) -> Result<TrainedModel, String>;

    /// Train on a D-way grid dataset (a factor list instead of a vertex
    /// pair). Default implementation errors; estimators that understand
    /// tensor-product chains (like the ridge [`Learner`]) override it.
    fn fit_tensor(&self, data: &TensorDataset) -> Result<TrainedModel, String> {
        let _ = data;
        Err("this estimator does not support tensor-chain datasets".into())
    }
}

/// Loss selector for the generic truncated-Newton path
/// ([`Learner::newton`]) — the Table-2 losses of the paper.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NewtonLoss {
    /// Squared loss (ridge regression through Algorithm 2).
    Ridge,
    /// Logistic loss.
    Logistic,
    /// L2-SVM (squared hinge) loss.
    L2Svm,
    /// RankRLS (magnitude-preserving ranking) loss — dual only.
    RankRls,
}

impl NewtonLoss {
    /// Canonical name (matches [`crate::losses::Loss::name`]).
    pub fn name(&self) -> &'static str {
        match self {
            NewtonLoss::Ridge => "ridge",
            NewtonLoss::Logistic => "logistic",
            NewtonLoss::L2Svm => "l2svm",
            NewtonLoss::RankRls => "rankrls",
        }
    }
}

/// Which specialized trainer a [`Learner`] drives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Ridge,
    Svm,
    Newton(NewtonLoss),
    Stochastic,
}

impl Kind {
    /// Short name for error messages.
    fn describe(&self) -> &'static str {
        match self {
            Kind::Ridge => "ridge",
            Kind::Svm => "svm",
            Kind::Newton(_) => "newton",
            Kind::Stochastic => "stochastic",
        }
    }
}

/// Fluent builder over every trainer in [`crate::train`]: Kronecker ridge
/// (dual MINRES, the multi-λ [`Learner::fit_path`], and the primal CG path),
/// the Kronecker L2-SVM, and the generic truncated-Newton trainers — all
/// returning one unified [`TrainedModel`].
///
/// Method-specific knobs (λ, kernels, iteration budgets) live here; the
/// execution policy (threads, workspace retention, cache sizing) is a single
/// [`Compute`] value set via [`Learner::compute`], and the pairwise kernel
/// family via [`Learner::pairwise`] — neither is duplicated on the
/// per-method config structs anymore.
#[derive(Debug, Clone)]
pub struct Learner {
    kind: Kind,
    lambda: f64,
    kernel_d: KernelKind,
    kernel_t: KernelKind,
    /// Ridge: MINRES iterations. SVM / Newton: outer (Newton) iterations.
    iterations: usize,
    /// SVM / Newton: inner solver iterations per Newton step.
    inner_iterations: usize,
    /// Ridge: residual tolerance of the MINRES solve.
    tol: f64,
    /// SVM / Newton: step size δ.
    delta: f64,
    /// SVM: snap |aᵢ| below this to exact zero after each step.
    sparsity_threshold: f64,
    trace: bool,
    patience: usize,
    primal: bool,
    pairwise: PairwiseKernelKind,
    solver: RidgeSolver,
    compute: Compute,
    /// Tensor path only: one kernel per grid mode (empty = broadcast
    /// `kernel_d` to every mode).
    mode_kernels: Vec<KernelKind>,
    /// Stochastic: sampler seed (default 1, the CLI `--seed` default).
    seed: u64,
    /// Stochastic: edges per mini-batch (default 512).
    batch_edges: usize,
    /// Stochastic: batch sampling mode.
    sampling: SamplingMode,
    /// Stochastic: step-size policy.
    step: StepPolicy,
}

impl Learner {
    fn new(kind: Kind, iterations: usize, inner_iterations: usize) -> Learner {
        Learner {
            kind,
            lambda: 1.0,
            kernel_d: KernelKind::Linear,
            kernel_t: KernelKind::Linear,
            iterations,
            inner_iterations,
            tol: 1e-9,
            delta: 1.0,
            sparsity_threshold: 1e-12,
            trace: false,
            patience: 0,
            primal: false,
            pairwise: PairwiseKernelKind::Kronecker,
            solver: RidgeSolver::Auto,
            compute: Compute::default(),
            mode_kernels: Vec::new(),
            seed: 1,
            batch_edges: 512,
            sampling: SamplingMode::EpochShuffle,
            step: StepPolicy::Auto,
        }
    }

    /// Kronecker ridge regression (§4.1): one MINRES solve, default 100
    /// iterations.
    pub fn ridge() -> Learner {
        Learner::new(Kind::Ridge, 100, 0)
    }

    /// Kronecker L2-SVM (§4.2): truncated Newton, default 10×10 iterations.
    pub fn svm() -> Learner {
        Learner::new(Kind::Svm, 10, 10)
    }

    /// Generic truncated-Newton trainer (Algorithms 2–3) over a Table-2
    /// loss, default 10×10 iterations.
    pub fn newton(loss: NewtonLoss) -> Learner {
        Learner::new(Kind::Newton(loss), 10, 10)
    }

    /// Stochastic mini-batch dual ridge trainer
    /// ([`crate::train::stochastic`]): sampled-GVT block coordinate
    /// descent, default 30 epochs ([`Learner::iterations`] sets the epoch
    /// budget). Tune with [`Learner::batch_edges`], [`Learner::seed`],
    /// [`Learner::sampling`], and [`Learner::step`]; Kronecker pairwise
    /// family and dual models only.
    pub fn stochastic() -> Learner {
        Learner::new(Kind::Stochastic, 30, 0)
    }

    /// Stochastic only: sampler seed (default 1, matching the CLI `--seed`
    /// default — runs are reproducible even when the seed is never set).
    pub fn seed(mut self, seed: u64) -> Learner {
        self.seed = seed;
        self
    }

    /// Stochastic only: edges per mini-batch (default 512).
    pub fn batch_edges(mut self, batch_edges: usize) -> Learner {
        self.batch_edges = batch_edges;
        self
    }

    /// Stochastic only: batch sampling mode (default
    /// [`SamplingMode::EpochShuffle`]).
    pub fn sampling(mut self, sampling: SamplingMode) -> Learner {
        self.sampling = sampling;
        self
    }

    /// Stochastic only: step-size policy (default [`StepPolicy::Auto`],
    /// the per-batch safe trace bound).
    pub fn step(mut self, step: StepPolicy) -> Learner {
        self.step = step;
        self
    }

    /// Set the regularization parameter λ.
    pub fn lambda(mut self, lambda: f64) -> Learner {
        self.lambda = lambda;
        self
    }

    /// Use `kernel` for both vertex roles.
    pub fn kernel(mut self, kernel: KernelKind) -> Learner {
        self.kernel_d = kernel;
        self.kernel_t = kernel;
        self
    }

    /// Use distinct start- and end-vertex kernels.
    pub fn kernels(mut self, kernel_d: KernelKind, kernel_t: KernelKind) -> Learner {
        self.kernel_d = kernel_d;
        self.kernel_t = kernel_t;
        self
    }

    /// Iteration budget: MINRES iterations for ridge, outer Newton
    /// iterations for SVM / Newton.
    pub fn iterations(mut self, iterations: usize) -> Learner {
        self.iterations = iterations;
        self
    }

    /// Inner solver iterations per Newton step (SVM / Newton only).
    pub fn inner_iterations(mut self, inner: usize) -> Learner {
        self.inner_iterations = inner;
        self
    }

    /// Residual tolerance of the ridge MINRES solve.
    pub fn tol(mut self, tol: f64) -> Learner {
        self.tol = tol;
        self
    }

    /// Newton step size δ (SVM / Newton only; the paper uses the constant 1).
    pub fn delta(mut self, delta: f64) -> Learner {
        self.delta = delta;
        self
    }

    /// SVM only: snap |aᵢ| below this to exact zero after each Newton step
    /// (keeps the sparse prediction shortcut effective).
    pub fn sparsity_threshold(mut self, threshold: f64) -> Learner {
        self.sparsity_threshold = threshold;
        self
    }

    /// Record the per-iteration risk (and validation AUC under
    /// [`Learner::fit_with_validation`]) into the returned model's trace.
    pub fn trace(mut self, trace: bool) -> Learner {
        self.trace = trace;
        self
    }

    /// Early-stopping patience on validation AUC (0 disables; takes effect
    /// under [`Learner::fit_with_validation`]).
    pub fn patience(mut self, patience: usize) -> Learner {
        self.patience = patience;
        self
    }

    /// Train the primal (linear-kernel, explicit-feature) model instead of
    /// the dual. Requires the Kronecker pairwise family; the configured
    /// kernels are ignored (implicitly linear).
    pub fn primal(mut self, primal: bool) -> Learner {
        self.primal = primal;
        self
    }

    /// Select the pairwise kernel family composed over the GVT engine.
    pub fn pairwise(mut self, pairwise: PairwiseKernelKind) -> Learner {
        self.pairwise = pairwise;
        self
    }

    /// Select the dual ridge solver (default [`RidgeSolver::Auto`], which
    /// takes the closed-form eigendecomposition fast path on complete
    /// training graphs and MINRES otherwise). Dual ridge only; other
    /// learners ignore it.
    pub fn solver(mut self, solver: RidgeSolver) -> Learner {
        self.solver = solver;
        self
    }

    /// Set the execution policy (threads, workspace retention, cache
    /// sizing). Transparent to results — see [`Compute`].
    pub fn compute(mut self, compute: Compute) -> Learner {
        self.compute = compute;
        self
    }

    /// Tensor path only: set one kernel per grid mode for
    /// [`Learner::fit_tensor`]. When unset, `kernel_d` (see
    /// [`Learner::kernel`]) is broadcast to every mode.
    pub fn mode_kernels(mut self, kernels: Vec<KernelKind>) -> Learner {
        self.mode_kernels = kernels;
        self
    }

    fn ridge_cfg(&self) -> RidgeConfig {
        RidgeConfig {
            lambda: self.lambda,
            kernel_d: self.kernel_d,
            kernel_t: self.kernel_t,
            iterations: self.iterations,
            tol: self.tol,
            trace: self.trace,
            patience: self.patience,
        }
    }

    fn svm_cfg(&self) -> SvmConfig {
        SvmConfig {
            lambda: self.lambda,
            kernel_d: self.kernel_d,
            kernel_t: self.kernel_t,
            outer_iters: self.iterations,
            inner_iters: self.inner_iterations,
            delta: self.delta,
            trace: self.trace,
            patience: self.patience,
            sparsity_threshold: self.sparsity_threshold,
        }
    }

    fn stochastic_cfg(&self) -> StochasticConfig {
        StochasticConfig {
            lambda: self.lambda,
            kernel_d: self.kernel_d,
            kernel_t: self.kernel_t,
            batch_edges: self.batch_edges,
            epochs: self.iterations,
            seed: self.seed,
            sampling: self.sampling,
            step: self.step,
            tol: self.tol,
            snapshot_every: 1,
            patience: self.patience,
        }
    }

    fn newton_cfg(&self) -> NewtonConfig {
        NewtonConfig {
            lambda: self.lambda,
            kernel_d: self.kernel_d,
            kernel_t: self.kernel_t,
            outer_iters: self.iterations,
            inner_iters: self.inner_iterations,
            delta: self.delta,
            trace: self.trace,
            patience: self.patience,
        }
    }

    /// Train on `train`, optionally monitoring `val` for the trace and the
    /// early-stopping rule. [`Estimator::fit`] is this with `val = None`.
    pub fn fit_with_validation(
        &self,
        train: &Dataset,
        val: Option<&Dataset>,
    ) -> Result<TrainedModel, String> {
        match self.kind {
            Kind::Ridge => {
                let trainer = KronRidge::new(self.ridge_cfg())
                    .with_pairwise(self.pairwise)
                    .with_solver(self.solver)
                    .with_compute(self.compute);
                if self.primal {
                    let (model, trace) = trainer.fit_primal(train, val)?;
                    Ok(TrainedModel::from_primal(model, self.lambda).with_trace(trace))
                } else {
                    let (model, trace) = trainer.fit_traced(train, val)?;
                    Ok(TrainedModel::from_dual(model, self.lambda).with_trace(trace))
                }
            }
            Kind::Svm => {
                let trainer = KronSvm::new(self.svm_cfg())
                    .with_pairwise(self.pairwise)
                    .with_compute(self.compute);
                if self.primal {
                    let (model, trace) = trainer.fit_primal(train, val)?;
                    Ok(TrainedModel::from_primal(model, self.lambda).with_trace(trace))
                } else {
                    let (model, trace) = trainer.fit_traced(train, val)?;
                    Ok(TrainedModel::from_dual(model, self.lambda).with_trace(trace))
                }
            }
            Kind::Newton(loss) => self.fit_newton(loss, train, val),
            Kind::Stochastic => {
                if self.primal {
                    return Err("the stochastic trainer is dual-only; drop .primal(true), or \
                                use Learner::ridge().primal(true) for the primal CG path"
                        .into());
                }
                if self.pairwise != PairwiseKernelKind::Kronecker {
                    return Err(format!(
                        "the stochastic trainer supports the Kronecker pairwise family only \
                         (got '{}'); use Learner::ridge() for the other families",
                        self.pairwise.name()
                    ));
                }
                let (model, trace) =
                    fit_stochastic(train, val, &self.stochastic_cfg(), &self.compute)?;
                Ok(TrainedModel::from_dual(model, self.lambda).with_trace(trace))
            }
        }
    }

    fn fit_newton(
        &self,
        loss: NewtonLoss,
        train: &Dataset,
        val: Option<&Dataset>,
    ) -> Result<TrainedModel, String> {
        let cfg = self.newton_cfg();
        // One monomorphized trainer per loss; the dispatch happens once here
        // rather than leaking a trait object into the solver loops.
        macro_rules! run {
            ($loss:expr) => {{
                let trainer = NewtonTrainer::new($loss, cfg)
                    .with_pairwise(self.pairwise)
                    .with_compute(self.compute);
                if self.primal {
                    let (model, trace) = trainer.fit_primal(train, val)?;
                    Ok(TrainedModel::from_primal(model, self.lambda).with_trace(trace))
                } else {
                    let (model, trace) = trainer.fit_dual(train, val)?;
                    Ok(TrainedModel::from_dual(model, self.lambda).with_trace(trace))
                }
            }};
        }
        match loss {
            NewtonLoss::Ridge => run!(RidgeLoss),
            NewtonLoss::Logistic => run!(LogisticLoss),
            NewtonLoss::L2Svm => run!(L2SvmLoss),
            NewtonLoss::RankRls => run!(RankRlsLoss),
        }
    }

    /// Train the whole regularization path in one batched block-CG solve
    /// (the builder's `lambda` is ignored; one [`TrainedModel`] per λ, see
    /// [`KronRidge::fit_path`]). Dual ridge only.
    pub fn fit_path(
        &self,
        train: &Dataset,
        lambdas: &[f64],
    ) -> Result<Vec<TrainedModel>, String> {
        if self.kind != Kind::Ridge || self.primal {
            return Err(format!(
                "Learner::fit_path trains a regularization path for the dual ridge learner \
                 only (this learner is {}{}); construct it with Learner::ridge() without \
                 .primal(true), or train one model per λ through fit / fit_with_validation",
                self.kind.describe(),
                if self.primal { ", primal" } else { "" }
            ));
        }
        let trainer = KronRidge::new(self.ridge_cfg())
            .with_pairwise(self.pairwise)
            .with_solver(self.solver)
            .with_compute(self.compute);
        let models = trainer.fit_path(train, lambdas)?;
        Ok(models
            .into_iter()
            .zip(lambdas)
            .map(|(model, &lambda)| TrainedModel::from_dual(model, lambda))
            .collect())
    }

    /// Train on `data` (no validation monitoring). Also available through
    /// the [`Estimator`] trait for generic code.
    pub fn fit(&self, data: &Dataset) -> Result<TrainedModel, String> {
        self.fit_with_validation(data, None)
    }

    fn tensor_cfg(&self, order: usize) -> Result<TensorRidgeConfig, String> {
        if self.kind != Kind::Ridge || self.primal {
            return Err(format!(
                "Learner::fit_tensor / fit_tensor_path support the dual ridge learner only \
                 (this learner is {}{}); construct it with Learner::ridge() without \
                 .primal(true)",
                self.kind.describe(),
                if self.primal { ", primal" } else { "" }
            ));
        }
        if self.pairwise != PairwiseKernelKind::Kronecker {
            return Err(format!(
                "tensor-chain training requires the Kronecker pairwise family, not {}",
                self.pairwise.name()
            ));
        }
        let kernels = if self.mode_kernels.is_empty() {
            vec![self.kernel_d; order]
        } else {
            self.mode_kernels.clone()
        };
        Ok(TensorRidgeConfig {
            lambda: self.lambda,
            kernels,
            iterations: self.iterations,
            tol: self.tol,
        })
    }

    /// Train ridge regression on a D-way grid dataset — the factor-list
    /// analogue of [`Learner::fit`]. Uses the per-mode kernels set via
    /// [`Learner::mode_kernels`] (falling back to broadcasting `kernel_d`).
    /// Dual ridge only.
    pub fn fit_tensor(&self, data: &TensorDataset) -> Result<TrainedModel, String> {
        let cfg = self.tensor_cfg(data.order())?;
        let model = TensorRidge::new(cfg).with_compute(self.compute).fit(data)?;
        Ok(TrainedModel::from_tensor(model, self.lambda))
    }

    /// Train the whole regularization path on a D-way grid dataset in one
    /// batched block-CG solve (the builder's `lambda` is ignored; one
    /// [`TrainedModel`] per λ). Dual ridge only.
    pub fn fit_tensor_path(
        &self,
        data: &TensorDataset,
        lambdas: &[f64],
    ) -> Result<Vec<TrainedModel>, String> {
        let cfg = self.tensor_cfg(data.order())?;
        let models = TensorRidge::new(cfg).with_compute(self.compute).fit_path(data, lambdas)?;
        Ok(models
            .into_iter()
            .zip(lambdas)
            .map(|(model, &lambda)| TrainedModel::from_tensor(model, lambda))
            .collect())
    }
}

impl Estimator for Learner {
    fn fit(&self, data: &Dataset) -> Result<TrainedModel, String> {
        self.fit_with_validation(data, None)
    }

    fn fit_tensor(&self, data: &TensorDataset) -> Result<TrainedModel, String> {
        Learner::fit_tensor(self, data)
    }
}
