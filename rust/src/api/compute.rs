//! The [`Compute`] execution policy — the single home of the knobs that
//! used to be re-declared on every config struct (`RidgeConfig`,
//! `SvmConfig`, `NewtonConfig`, `ServerConfig` each carried their own
//! `threads`, and serving additionally its own cache size).

use crate::gvt::engine::DEFAULT_POOL_RETENTION;

/// Execution policy shared by training, prediction, and serving.
///
/// Every knob here is **transparent to results**: threading is bitwise
/// deterministic (the GVT engine and the packed GEMM replay identical
/// reductions at every thread count), the workspace retention bound is a
/// scratch-memory recycling policy, and kernel-row cache hits reproduce
/// freshly computed rows bit for bit. A `Compute` only changes how fast an
/// answer arrives and how much memory is held between calls — never the
/// answer.
///
/// Consumers take it **by reference** (`&Compute`): trainers
/// ([`KronRidge`](crate::train::KronRidge), [`KronSvm`](crate::train::KronSvm),
/// [`NewtonTrainer`](crate::train::NewtonTrainer) via
/// `with_compute`), the [`Learner`](super::Learner) builder (`.compute(…)`),
/// [`DualModel::predict_context`](crate::model::DualModel::predict_context),
/// and the prediction server
/// ([`ServerConfig`](crate::coordinator::ServerConfig)`::compute`).
///
/// ```
/// use kronvt::api::Compute;
/// let policy = Compute::threads(4).with_cache_vertices(512);
/// assert_eq!(policy.threads, 4);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Compute {
    /// Worker threads per GVT matvec / kernel GEMM (`0` = all cores,
    /// `1` = serial). Results are bitwise identical for every value.
    pub threads: usize,
    /// Bound on idle scratch workspaces retained by each operator's
    /// [`WorkspacePool`](crate::gvt::WorkspacePool) (`0` disables
    /// recycling). Bounds steady-state scratch memory; does not affect
    /// results.
    pub workspace_retention: usize,
    /// Per-side capacity (in vertices) of the serving kernel-row LRU cache
    /// (`0` disables caching). Only prediction contexts and the server read
    /// this; cache hits are bitwise identical to recomputed rows.
    pub cache_vertices: usize,
}

impl Default for Compute {
    fn default() -> Self {
        Compute {
            threads: 1,
            workspace_retention: DEFAULT_POOL_RETENTION,
            cache_vertices: 1024,
        }
    }
}

impl Compute {
    /// Serial policy (one thread), default retention and cache.
    pub fn serial() -> Compute {
        Compute::default()
    }

    /// Policy sharding every matvec over `n` worker threads (`0` = all
    /// cores); everything else defaulted.
    pub fn threads(n: usize) -> Compute {
        Compute { threads: n, ..Compute::default() }
    }

    /// Policy using every available core.
    pub fn all_cores() -> Compute {
        Compute::threads(0)
    }

    /// Replace the thread count.
    pub fn with_threads(mut self, n: usize) -> Compute {
        self.threads = n;
        self
    }

    /// Replace the workspace-pool retention bound.
    pub fn with_workspace_retention(mut self, retention: usize) -> Compute {
        self.workspace_retention = retention;
        self
    }

    /// Replace the serving kernel-row cache capacity (`0` disables).
    pub fn with_cache_vertices(mut self, vertices: usize) -> Compute {
        self.cache_vertices = vertices;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builders_compose() {
        let c = Compute::threads(4).with_cache_vertices(64).with_workspace_retention(2);
        assert_eq!(c, Compute { threads: 4, workspace_retention: 2, cache_vertices: 64 });
        assert_eq!(Compute::all_cores().threads, 0);
        assert_eq!(Compute::serial(), Compute::default());
        assert_eq!(Compute::default().workspace_retention, DEFAULT_POOL_RETENTION);
    }
}
