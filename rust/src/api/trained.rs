//! [`TrainedModel`] — the unified result of every [`Estimator`] in the
//! crate, wrapping a dual, primal, or D-way tensor-chain predictor,
//! carrying its training metadata (λ, per-iteration trace), and providing
//! the portable `kronvt-model/v1` / `v2` persistence used by
//! `train --save` / `predict` / `serve --model`.
//!
//! [`Estimator`]: super::Estimator

use std::path::Path;

use super::artifact;
use super::Compute;
use crate::coordinator::{PredictServer, ServerConfig};
use crate::data::{Dataset, TensorDataset};
use crate::model::{DualModel, PredictContext, PrimalModel, TensorModel};
use crate::train::TrainTrace;

/// The two predictor shapes a [`TrainedModel`] can wrap.
#[derive(Debug, Clone)]
pub(crate) enum ModelInner {
    /// Kernel (dual) predictor: coefficients over the training edges plus
    /// the training-side features needed to evaluate test–train kernels.
    Dual(DualModel),
    /// Linear (primal) predictor: the flat weight vector `w ∈ R^{d·r}`.
    Primal(PrimalModel),
    /// D-way tensor-chain (dual) predictor: coefficients over the training
    /// cells plus per-mode features and kernels.
    Tensor(TensorModel),
}

/// A trained model with one lifecycle: **fit → save → load → serve**.
///
/// Produced by [`Learner::fit`](super::Learner::fit) (or the
/// [`Estimator`](super::Estimator) trait), a `TrainedModel` predicts
/// in-process ([`TrainedModel::predict`], [`TrainedModel::predict_batch`]),
/// converts into a long-lived serving context
/// ([`TrainedModel::into_context`]) or a full prediction server
/// ([`TrainedModel::serve`]), and round-trips through the versioned
/// `kronvt-model` JSON artifact ([`TrainedModel::save`] /
/// [`TrainedModel::load`]) with **bitwise-identical** predictions after
/// reload — every `f64` (duals, features, kernel hyperparameters) is
/// serialized with exact shortest-round-trip encoding.
///
/// ```
/// use kronvt::api::{Compute, Learner, TrainedModel};
/// use kronvt::data::checkerboard::CheckerboardConfig;
///
/// let data = CheckerboardConfig { m: 30, q: 30, density: 0.25, noise: 0.2, feature_range: 8.0, seed: 3 }
///     .generate();
/// let model = Learner::ridge()
///     .lambda(1e-2)
///     .iterations(50)
///     .compute(Compute::serial())
///     .fit(&data)
///     .unwrap();
///
/// let path = std::env::temp_dir().join(format!("kronvt_trained_doc_{}.json", std::process::id()));
/// model.save(&path).unwrap();
/// let loaded = TrainedModel::load(&path).unwrap();
/// std::fs::remove_file(&path).ok();
/// assert_eq!(loaded.predict(&data), model.predict(&data)); // bitwise
/// ```
#[derive(Debug, Clone)]
pub struct TrainedModel {
    pub(crate) inner: ModelInner,
    pub(crate) lambda: f64,
    pub(crate) trace: TrainTrace,
}

impl TrainedModel {
    /// Wrap a dual model trained with regularization `lambda`.
    pub fn from_dual(model: DualModel, lambda: f64) -> TrainedModel {
        TrainedModel { inner: ModelInner::Dual(model), lambda, trace: TrainTrace::default() }
    }

    /// Wrap a primal model trained with regularization `lambda`.
    pub fn from_primal(model: PrimalModel, lambda: f64) -> TrainedModel {
        TrainedModel { inner: ModelInner::Primal(model), lambda, trace: TrainTrace::default() }
    }

    /// Wrap a D-way tensor-chain model trained with regularization `lambda`.
    pub fn from_tensor(model: TensorModel, lambda: f64) -> TrainedModel {
        TrainedModel { inner: ModelInner::Tensor(model), lambda, trace: TrainTrace::default() }
    }

    /// Attach the per-iteration training trace (risk / validation AUC) —
    /// persisted into the artifact as training metadata.
    pub fn with_trace(mut self, trace: TrainTrace) -> TrainedModel {
        self.trace = trace;
        self
    }

    /// The regularization parameter λ the model was trained with.
    pub fn lambda(&self) -> f64 {
        self.lambda
    }

    /// The per-iteration training trace (empty unless tracing was enabled).
    pub fn trace(&self) -> &TrainTrace {
        &self.trace
    }

    /// Start- and end-vertex feature dimensions `(d, r)` the model expects
    /// from every prediction batch — callers can validate incoming data
    /// against these instead of hitting an internal dimension assert.
    /// For tensor models this reports modes `(1, 0)`, which matches
    /// `(start, end)` under the crate's `G ⊗ K` mode ordering.
    pub fn feature_dims(&self) -> (usize, usize) {
        match &self.inner {
            ModelInner::Dual(m) => {
                (m.train_start_features.cols(), m.train_end_features.cols())
            }
            ModelInner::Primal(m) => (m.d_features, m.r_features),
            ModelInner::Tensor(m) => {
                (m.train_features[1].cols(), m.train_features[0].cols())
            }
        }
    }

    /// `"dual"`, `"primal"`, or `"tensor"` — the artifact `kind` tag.
    pub fn kind_name(&self) -> &'static str {
        match &self.inner {
            ModelInner::Dual(_) => "dual",
            ModelInner::Primal(_) => "primal",
            ModelInner::Tensor(_) => "tensor",
        }
    }

    /// The wrapped dual model, if this is a kernel predictor.
    pub fn as_dual(&self) -> Option<&DualModel> {
        match &self.inner {
            ModelInner::Dual(m) => Some(m),
            _ => None,
        }
    }

    /// The wrapped primal model, if this is a linear predictor.
    pub fn as_primal(&self) -> Option<&PrimalModel> {
        match &self.inner {
            ModelInner::Primal(m) => Some(m),
            _ => None,
        }
    }

    /// The wrapped tensor-chain model, if this is a D-way grid predictor.
    pub fn as_tensor(&self) -> Option<&TensorModel> {
        match &self.inner {
            ModelInner::Tensor(m) => Some(m),
            _ => None,
        }
    }

    /// Unwrap into the dual model, erroring for other model kinds.
    pub fn into_dual(self) -> Result<DualModel, String> {
        match self.inner {
            ModelInner::Dual(m) => Ok(m),
            ModelInner::Primal(_) => Err("this artifact holds a primal (linear) model".into()),
            ModelInner::Tensor(_) => Err("this artifact holds a tensor-chain model".into()),
        }
    }

    /// Predict scores for every edge of `test` (serial; see
    /// [`TrainedModel::predict_batch`] for the policy-driven path).
    ///
    /// Tensor models accept a bipartite `test` only at `D = 2` (it is viewed
    /// as a two-mode grid); higher orders need
    /// [`TrainedModel::predict_tensor`]. Panics on incompatible test data —
    /// prevalidate via [`TrainedModel::feature_dims`].
    pub fn predict(&self, test: &Dataset) -> Vec<f64> {
        match &self.inner {
            ModelInner::Dual(m) => m.predict(test),
            ModelInner::Primal(m) => m.predict(test),
            ModelInner::Tensor(m) => m
                .predict(&TensorDataset::from_dataset(test))
                .expect("bipartite test data is incompatible with this tensor model"),
        }
    }

    /// Predict scores for one batch of test edges under a [`Compute`]
    /// policy: dual models shard the kernel-block builds and the GVT matvec
    /// over `compute.threads` (bitwise identical to the serial path); primal
    /// models score with their single GEMM. For repeated batches against one
    /// model, build a context once via [`TrainedModel::into_context`]
    /// instead.
    pub fn predict_batch(&self, test: &Dataset, compute: &Compute) -> Vec<f64> {
        match &self.inner {
            ModelInner::Dual(m) => m.predict_threaded(test, compute.threads),
            ModelInner::Primal(m) => m.predict(test),
            ModelInner::Tensor(m) => m
                .predict_threaded(&TensorDataset::from_dataset(test), compute.threads)
                .expect("bipartite test data is incompatible with this tensor model"),
        }
    }

    /// Predict scores for the cells of a D-way grid dataset. Tensor models
    /// only; dual and primal models score bipartite data via
    /// [`TrainedModel::predict`] / [`TrainedModel::predict_batch`].
    pub fn predict_tensor(
        &self,
        test: &TensorDataset,
        compute: &Compute,
    ) -> Result<Vec<f64>, String> {
        match &self.inner {
            ModelInner::Tensor(m) => m.predict_threaded(test, compute.threads),
            _ => Err("this model was trained on bipartite data; use predict/predict_batch".into()),
        }
    }

    /// Convert into a long-lived serving context
    /// ([`PredictContext`]): duals pruned once, train-side
    /// [`EdgePlan`](crate::gvt::EdgePlan)s prebuilt, pooled workspaces
    /// (bounded by `compute.workspace_retention`), and a per-vertex
    /// kernel-row LRU of `compute.cache_vertices` per side. Errors for
    /// primal models, whose per-batch GEMM needs no context.
    pub fn into_context(self, compute: &Compute) -> Result<PredictContext, String> {
        match self.inner {
            ModelInner::Dual(m) => Ok(m.predict_context(compute)),
            ModelInner::Primal(_) => {
                Err("serving contexts require a dual model (primal predicts directly)".into())
            }
            ModelInner::Tensor(_) => Err(
                "serving contexts require a two-factor dual model (tensor models predict \
                 directly via predict_tensor)"
                    .into(),
            ),
        }
    }

    /// Spin up a batched prediction server around this model — the
    /// `serve --model` path: a loaded artifact serves without retraining.
    /// Errors for primal models.
    pub fn serve(self, cfg: ServerConfig) -> Result<PredictServer, String> {
        match self.inner {
            ModelInner::Dual(m) => Ok(PredictServer::start(m, cfg)),
            ModelInner::Primal(_) | ModelInner::Tensor(_) => {
                Err("the prediction server requires a two-factor dual model".into())
            }
        }
    }

    /// Write the portable JSON artifact (`kronvt-model/v1` for dual and
    /// primal models, `kronvt-model/v2` for tensor-chain models). Errors if
    /// any model parameter is non-finite (the artifact format refuses lossy
    /// `NaN`/`inf` encodings) or on I/O failure.
    ///
    /// The write is **crash-safe**: the document is staged in a `.tmp`
    /// sibling, fsynced, and renamed over `path`, so a crash at any point
    /// leaves either the previous artifact or the complete new one — never
    /// a torn file. A save that fails (e.g. non-finite parameters) leaves
    /// an existing artifact at `path` untouched.
    pub fn save(&self, path: &Path) -> Result<(), String> {
        let text = artifact::to_json(self)?.dump()?;
        artifact::save_atomic(path, &format!("{text}\n"))
    }

    /// Load a `kronvt-model/v1` or `/v2` artifact written by
    /// [`TrainedModel::save`].
    /// The loaded model predicts **bitwise identically** to the one that was
    /// saved. Corrupted documents, schema violations, and unsupported
    /// versions are rejected with a clear error.
    ///
    /// `.tmp` staging files are never valid load targets (they may be
    /// mid-write from a crashed save) and are rejected by name; after a
    /// successful load, a stale `.tmp` sibling of `path` is swept away.
    pub fn load(path: &Path) -> Result<TrainedModel, String> {
        if path.extension().is_some_and(|e| e == "tmp") {
            return Err(format!(
                "{}: refusing to load a .tmp staging file (possibly a torn write \
                 from a crashed save); load the real artifact path instead",
                path.display()
            ));
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("read {}: {e}", path.display()))?;
        let json = crate::util::json::Json::parse(&text)
            .map_err(|e| format!("{}: invalid JSON: {e}", path.display()))?;
        let model = artifact::from_json(&json).map_err(|e| format!("{}: {e}", path.display()))?;
        // The artifact at `path` is good — a stale sibling can only be junk
        // from a save that crashed between staging and rename.
        let _ = std::fs::remove_file(artifact::tmp_sibling(path));
        Ok(model)
    }
}
