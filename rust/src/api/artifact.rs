//! The portable `kronvt-model` artifact: a versioned JSON document holding
//! everything a fresh process needs to reproduce a trained model's
//! predictions **bit for bit** — dual coefficients (or primal weights), the
//! pairwise kernel family, kernel hyperparameters, the training vertex
//! features and edge index, λ, and the regularization (training) trace.
//!
//! Two format versions coexist: dual and primal models write
//! `kronvt-model/v1` (unchanged from earlier builds, so old readers keep
//! working), and D-way tensor-chain models write `kronvt-model/v2`, which
//! stores one kernel, one feature matrix, and one index column **per
//! mode**. This build loads both.
//!
//! Fidelity rests on two properties of [`crate::util::json`]:
//!
//! * every `f64` is written with shortest-round-trip decimal encoding
//!   (including the `-0` sign), so parsing recovers the identical bit
//!   pattern;
//! * non-finite numbers are a serialization **error**, never a lossy
//!   `null`/bare-token stand-in — a model that trained to `NaN` cannot be
//!   silently persisted. (The optional trace metadata is the one exception:
//!   a non-finite risk/AUC entry is stored as `null`, since traces are
//!   diagnostics, not parameters.)
//!
//! See `docs/API.md` for the full schema.

use std::path::{Path, PathBuf};

use crate::gvt::{KronIndex, PairwiseKernelKind, TensorIndex};
use crate::kernels::KernelKind;
use crate::linalg::Matrix;
use crate::model::{DualModel, PrimalModel, TensorModel};
use crate::train::{IterRecord, TrainTrace};
use crate::util::json::Json;

use super::trained::ModelInner;
use super::TrainedModel;

/// The artifact format identifier written for dual and primal models.
pub const FORMAT: &str = "kronvt-model/v1";

/// The artifact format identifier written for tensor-chain models (per-mode
/// kernels / features / index columns). This build reads both versions.
pub const FORMAT_V2: &str = "kronvt-model/v2";

/// Error unless every entry of `xs` is finite. Applied on **both** sides of
/// the round trip: save refuses to write a lossy document, and load refuses
/// a hand-edited/corrupt one (`1e999` parses to `inf` through the JSON
/// number grammar, so schema checks alone would let it through).
fn ensure_finite(xs: &[f64], what: &str) -> Result<(), String> {
    match xs.iter().position(|x| !x.is_finite()) {
        Some(i) => Err(format!("{what}[{i}] is non-finite ({})", xs[i])),
        None => Ok(()),
    }
}

fn matrix_to_json(m: &Matrix) -> Json {
    Json::obj(vec![
        ("rows", Json::from(m.rows())),
        ("cols", Json::from(m.cols())),
        ("data", Json::num_arr(m.data())),
    ])
}

fn idx_to_json(idx: &KronIndex) -> Json {
    Json::obj(vec![
        ("left", Json::Arr(idx.left.iter().map(|&i| Json::from(i as usize)).collect())),
        ("right", Json::Arr(idx.right.iter().map(|&i| Json::from(i as usize)).collect())),
    ])
}

fn tensor_idx_to_json(idx: &TensorIndex) -> Json {
    Json::obj(vec![(
        "modes",
        Json::Arr(
            idx.modes
                .iter()
                .map(|col| Json::Arr(col.iter().map(|&i| Json::from(i as usize)).collect()))
                .collect(),
        ),
    )])
}

fn trace_to_json(trace: &TrainTrace) -> Json {
    let finite_or_null = |x: f64| if x.is_finite() { Json::Num(x) } else { Json::Null };
    Json::Arr(
        trace
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("iter", Json::from(r.iter)),
                    ("risk", finite_or_null(r.risk)),
                    ("val_auc", r.val_auc.map(finite_or_null).unwrap_or(Json::Null)),
                    ("elapsed_secs", finite_or_null(r.elapsed_secs)),
                ])
            })
            .collect(),
    )
}

/// Serialize a [`TrainedModel`] to its versioned document
/// (`kronvt-model/v1` for dual / primal, `kronvt-model/v2` for tensor).
pub fn to_json(model: &TrainedModel) -> Result<Json, String> {
    if !model.lambda.is_finite() {
        return Err(format!("lambda is non-finite ({})", model.lambda));
    }
    let format = match &model.inner {
        ModelInner::Tensor(_) => FORMAT_V2,
        ModelInner::Dual(_) | ModelInner::Primal(_) => FORMAT,
    };
    let mut pairs = vec![
        ("format", Json::from(format)),
        ("lambda", Json::Num(model.lambda)),
        ("trace", trace_to_json(&model.trace)),
    ];
    match &model.inner {
        ModelInner::Dual(m) => {
            ensure_finite(&m.dual_coef, "dual_coef")?;
            ensure_finite(m.train_start_features.data(), "train_start_features.data")?;
            ensure_finite(m.train_end_features.data(), "train_end_features.data")?;
            ensure_finite_kernel(m.kernel_d, "kernel_d")?;
            ensure_finite_kernel(m.kernel_t, "kernel_t")?;
            pairs.extend([
                ("kind", Json::from("dual")),
                ("pairwise", Json::from(m.pairwise.name())),
                ("kernel_d", Json::from(m.kernel_d.name())),
                ("kernel_t", Json::from(m.kernel_t.name())),
                ("dual_coef", Json::num_arr(&m.dual_coef)),
                ("train_idx", idx_to_json(&m.train_idx)),
                ("train_start_features", matrix_to_json(&m.train_start_features)),
                ("train_end_features", matrix_to_json(&m.train_end_features)),
            ]);
        }
        ModelInner::Primal(m) => {
            ensure_finite(&m.w, "w")?;
            pairs.extend([
                ("kind", Json::from("primal")),
                ("w", Json::num_arr(&m.w)),
                ("d_features", Json::from(m.d_features)),
                ("r_features", Json::from(m.r_features)),
            ]);
        }
        ModelInner::Tensor(m) => {
            m.validate()?;
            ensure_finite(&m.dual_coef, "dual_coef")?;
            for (d, f) in m.train_features.iter().enumerate() {
                ensure_finite(f.data(), &format!("train_features[{d}].data"))?;
            }
            for (d, &k) in m.kernels.iter().enumerate() {
                ensure_finite_kernel(k, &format!("mode_kernels[{d}]"))?;
            }
            pairs.extend([
                ("kind", Json::from("tensor")),
                (
                    "mode_kernels",
                    Json::Arr(m.kernels.iter().map(|k| Json::from(k.name())).collect()),
                ),
                ("dual_coef", Json::num_arr(&m.dual_coef)),
                ("train_idx", tensor_idx_to_json(&m.train_idx)),
                (
                    "train_features",
                    Json::Arr(m.train_features.iter().map(matrix_to_json).collect()),
                ),
            ]);
        }
    }
    Ok(Json::obj(pairs))
}

/// The kernel hyperparameters themselves must be finite, or the `name()` /
/// `parse()` round trip (and the kernel itself) is meaningless.
fn ensure_finite_kernel(kernel: KernelKind, what: &str) -> Result<(), String> {
    let ok = match kernel {
        KernelKind::Linear | KernelKind::Tanimoto => true,
        KernelKind::Gaussian { gamma } => gamma.is_finite(),
        KernelKind::Polynomial { gamma, coef0, .. } => gamma.is_finite() && coef0.is_finite(),
    };
    if ok {
        Ok(())
    } else {
        Err(format!("{what} has a non-finite hyperparameter"))
    }
}

fn require<'a>(json: &'a Json, key: &str) -> Result<&'a Json, String> {
    json.get(key).ok_or_else(|| format!("artifact is missing '{key}'"))
}

fn num_field(json: &Json, key: &str) -> Result<f64, String> {
    require(json, key)?
        .as_f64()
        .ok_or_else(|| format!("artifact field '{key}' must be a number"))
}

fn str_field<'a>(json: &'a Json, key: &str) -> Result<&'a str, String> {
    require(json, key)?
        .as_str()
        .ok_or_else(|| format!("artifact field '{key}' must be a string"))
}

fn usize_field(json: &Json, key: &str) -> Result<usize, String> {
    require(json, key)?
        .as_usize()
        .ok_or_else(|| format!("artifact field '{key}' must be a non-negative integer"))
}

fn num_vec(json: &Json, key: &str) -> Result<Vec<f64>, String> {
    require(json, key)?
        .as_arr()
        .ok_or_else(|| format!("artifact field '{key}' must be an array"))?
        .iter()
        .enumerate()
        .map(|(i, v)| {
            v.as_f64().ok_or_else(|| format!("artifact field '{key}[{i}]' must be a number"))
        })
        .collect()
}

fn u32_items(arr: &Json, what: &str) -> Result<Vec<u32>, String> {
    arr.as_arr()
        .ok_or_else(|| format!("artifact field '{what}' must be an array"))?
        .iter()
        .enumerate()
        .map(|(i, v)| {
            v.as_usize()
                .filter(|&n| n <= u32::MAX as usize)
                .map(|n| n as u32)
                .ok_or_else(|| format!("artifact field '{what}[{i}]' must be a vertex index"))
        })
        .collect()
}

fn u32_vec(json: &Json, key: &str) -> Result<Vec<u32>, String> {
    u32_items(require(json, key)?, key)
}

fn matrix_from_obj(obj: &Json, what: &str) -> Result<Matrix, String> {
    let rows = usize_field(obj, "rows").map_err(|e| format!("{what}: {e}"))?;
    let cols = usize_field(obj, "cols").map_err(|e| format!("{what}: {e}"))?;
    let data = num_vec(obj, "data").map_err(|e| format!("{what}: {e}"))?;
    // checked_mul: a corrupt artifact with absurd dimensions must be
    // rejected here, not wrap around and panic later inside predict.
    let expected = rows.checked_mul(cols).ok_or_else(|| {
        format!("artifact field '{what}' dimensions {rows}x{cols} overflow")
    })?;
    if data.len() != expected {
        return Err(format!(
            "artifact field '{what}' claims {rows}x{cols} but carries {} values",
            data.len()
        ));
    }
    Ok(Matrix::from_vec(rows, cols, data))
}

fn matrix_from_json(json: &Json, key: &str) -> Result<Matrix, String> {
    matrix_from_obj(require(json, key)?, key)
}

fn trace_from_json(json: &Json) -> TrainTrace {
    // The trace is diagnostic metadata: parse what is well-formed, default
    // the rest. A missing or malformed trace never fails a model load.
    let mut trace = TrainTrace::default();
    if let Some(records) = json.get("trace").and_then(|t| t.as_arr()) {
        for r in records {
            trace.push(IterRecord {
                iter: r.get("iter").and_then(|v| v.as_usize()).unwrap_or(0),
                risk: r.get("risk").and_then(|v| v.as_f64()).unwrap_or(f64::NAN),
                val_auc: r.get("val_auc").and_then(|v| v.as_f64()),
                elapsed_secs: r.get("elapsed_secs").and_then(|v| v.as_f64()).unwrap_or(0.0),
            });
        }
    }
    trace
}

/// Deserialize and validate a `kronvt-model/v1` or `/v2` document.
pub fn from_json(json: &Json) -> Result<TrainedModel, String> {
    match json.get("format").and_then(|f| f.as_str()) {
        Some(FORMAT) | Some(FORMAT_V2) => {}
        Some(other) if other.starts_with("kronvt-model/") => {
            return Err(format!(
                "unsupported model artifact version '{other}' (this build reads \
                 '{FORMAT}' and '{FORMAT_V2}')"
            ))
        }
        Some(other) => {
            return Err(format!("not a kronvt model artifact (format '{other}')"))
        }
        None => return Err("not a kronvt model artifact (missing 'format')".into()),
    }
    let lambda = num_field(json, "lambda")?;
    if !lambda.is_finite() {
        return Err(format!("lambda is non-finite ({lambda})"));
    }
    let trace = trace_from_json(json);
    let inner = match str_field(json, "kind")? {
        "dual" => ModelInner::Dual(dual_from_json(json)?),
        "primal" => ModelInner::Primal(primal_from_json(json)?),
        "tensor" => ModelInner::Tensor(tensor_from_json(json)?),
        other => return Err(format!("unknown model kind '{other}' (dual, primal, tensor)")),
    };
    Ok(TrainedModel { inner, lambda, trace })
}

fn dual_from_json(json: &Json) -> Result<DualModel, String> {
    let pairwise = PairwiseKernelKind::parse(str_field(json, "pairwise")?)?;
    let kernel_d = KernelKind::parse(str_field(json, "kernel_d")?)?;
    let kernel_t = KernelKind::parse(str_field(json, "kernel_t")?)?;
    let dual_coef = num_vec(json, "dual_coef")?;
    let idx_obj = require(json, "train_idx")?;
    let left = u32_vec(idx_obj, "left").map_err(|e| format!("train_idx: {e}"))?;
    let right = u32_vec(idx_obj, "right").map_err(|e| format!("train_idx: {e}"))?;
    if left.len() != right.len() {
        return Err(format!(
            "train_idx sides disagree: {} left vs {} right indices",
            left.len(),
            right.len()
        ));
    }
    let train_idx = KronIndex::new(left, right);
    if dual_coef.len() != train_idx.len() {
        return Err(format!(
            "dual_coef has {} entries but train_idx has {} edges",
            dual_coef.len(),
            train_idx.len()
        ));
    }
    let train_start_features = matrix_from_json(json, "train_start_features")?;
    let train_end_features = matrix_from_json(json, "train_end_features")?;
    train_idx
        .validate(train_end_features.rows(), train_start_features.rows())
        .map_err(|e| format!("train_idx: {e}"))?;
    pairwise.validate_vertex_domains(
        kernel_d,
        kernel_t,
        train_start_features.cols(),
        train_end_features.cols(),
    )?;
    // Mirror the save-side finiteness guarantee: a loaded model must never
    // silently degrade into NaN scores.
    ensure_finite(&dual_coef, "dual_coef")?;
    ensure_finite(train_start_features.data(), "train_start_features.data")?;
    ensure_finite(train_end_features.data(), "train_end_features.data")?;
    ensure_finite_kernel(kernel_d, "kernel_d")?;
    ensure_finite_kernel(kernel_t, "kernel_t")?;
    Ok(DualModel {
        dual_coef,
        train_start_features,
        train_end_features,
        train_idx,
        kernel_d,
        kernel_t,
        pairwise,
    })
}

fn primal_from_json(json: &Json) -> Result<PrimalModel, String> {
    let w = num_vec(json, "w")?;
    let d_features = usize_field(json, "d_features")?;
    let r_features = usize_field(json, "r_features")?;
    let expected = d_features.checked_mul(r_features).ok_or_else(|| {
        format!("primal dimensions {d_features}x{r_features} overflow")
    })?;
    if w.len() != expected {
        return Err(format!(
            "primal weights have {} entries but d_features·r_features = {expected}",
            w.len()
        ));
    }
    ensure_finite(&w, "w")?;
    Ok(PrimalModel { w, d_features, r_features })
}

fn tensor_from_json(json: &Json) -> Result<TensorModel, String> {
    let kernels: Vec<KernelKind> = require(json, "mode_kernels")?
        .as_arr()
        .ok_or_else(|| "artifact field 'mode_kernels' must be an array".to_string())?
        .iter()
        .enumerate()
        .map(|(d, v)| {
            v.as_str()
                .ok_or_else(|| format!("artifact field 'mode_kernels[{d}]' must be a string"))
                .and_then(KernelKind::parse)
        })
        .collect::<Result<_, _>>()?;
    let dual_coef = num_vec(json, "dual_coef")?;
    let idx_obj = require(json, "train_idx")?;
    let mode_arrs = require(idx_obj, "modes")
        .map_err(|e| format!("train_idx: {e}"))?
        .as_arr()
        .ok_or_else(|| "artifact field 'train_idx.modes' must be an array".to_string())?;
    let mut modes = Vec::with_capacity(mode_arrs.len());
    for (d, col) in mode_arrs.iter().enumerate() {
        modes.push(u32_items(col, &format!("train_idx.modes[{d}]"))?);
    }
    // Pre-check the TensorIndex invariants: a corrupt document must error,
    // not trip the constructor's assert.
    if modes.is_empty() {
        return Err("train_idx.modes must not be empty".into());
    }
    if let Some(d) = modes.iter().position(|col| col.len() != modes[0].len()) {
        return Err(format!(
            "train_idx.modes[{d}] has {} entries but mode 0 has {}",
            modes[d].len(),
            modes[0].len()
        ));
    }
    let train_idx = TensorIndex::new(modes);
    let train_features = require(json, "train_features")?
        .as_arr()
        .ok_or_else(|| "artifact field 'train_features' must be an array".to_string())?
        .iter()
        .enumerate()
        .map(|(d, obj)| matrix_from_obj(obj, &format!("train_features[{d}]")))
        .collect::<Result<Vec<_>, _>>()?;
    let model = TensorModel { dual_coef, train_features, train_idx, kernels };
    model.validate()?;
    // Mirror the save-side finiteness guarantee.
    ensure_finite(&model.dual_coef, "dual_coef")?;
    for (d, f) in model.train_features.iter().enumerate() {
        ensure_finite(f.data(), &format!("train_features[{d}].data"))?;
    }
    for (d, &k) in model.kernels.iter().enumerate() {
        ensure_finite_kernel(k, &format!("mode_kernels[{d}]"))?;
    }
    Ok(model)
}

/// The temporary sibling `save_atomic` stages through: the artifact path
/// with `.tmp` appended (`model.json` → `model.json.tmp`). The loader
/// refuses to read these and sweeps stale ones left by a crashed save.
pub(crate) fn tmp_sibling(path: &Path) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(".tmp");
    PathBuf::from(os)
}

/// Crash-safe file write: stage the full document in a `.tmp` sibling,
/// `fsync` it, then `rename` over the destination. On POSIX the rename is
/// atomic, so a crash at any point leaves either the previous artifact
/// intact or the complete new one — never a torn file at `path`. Any
/// failure cleans up the staging file before returning the error.
pub(crate) fn save_atomic(path: &Path, text: &str) -> Result<(), String> {
    use std::io::Write as _;

    let tmp = tmp_sibling(path);
    let staged = (|| -> std::io::Result<()> {
        let mut file = std::fs::File::create(&tmp)?;
        file.write_all(text.as_bytes())?;
        // Durability, not just atomicity: rename may be reordered before
        // the data blocks unless the staged file is synced first.
        file.sync_all()?;
        Ok(())
    })();
    if let Err(e) = staged {
        let _ = std::fs::remove_file(&tmp);
        return Err(format!("cannot stage artifact {}: {e}", tmp.display()));
    }
    if let Err(e) = std::fs::rename(&tmp, path) {
        let _ = std::fs::remove_file(&tmp);
        return Err(format!("cannot install artifact {}: {e}", path.display()));
    }
    // Best-effort directory fsync so the rename itself is durable; not all
    // platforms allow opening a directory for sync, so errors are ignored.
    if let Some(dir) = path.parent() {
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn toy_dual(seed: u64) -> TrainedModel {
        let mut rng = Pcg32::seeded(seed);
        let (m, q, n) = (5, 4, 11);
        TrainedModel::from_dual(
            DualModel {
                dual_coef: rng.normal_vec(n),
                train_start_features: Matrix::from_fn(m, 3, |_, _| rng.normal()),
                train_end_features: Matrix::from_fn(q, 2, |_, _| rng.normal()),
                train_idx: KronIndex::new(
                    (0..n).map(|_| rng.below(q) as u32).collect(),
                    (0..n).map(|_| rng.below(m) as u32).collect(),
                ),
                kernel_d: KernelKind::Gaussian { gamma: 0.1 + 1.0 / 3.0 },
                kernel_t: KernelKind::Linear,
                pairwise: PairwiseKernelKind::Kronecker,
            },
            2f64.powi(-7),
        )
    }

    #[test]
    fn dual_document_round_trips_bitwise() {
        let model = toy_dual(50);
        let text = to_json(&model).unwrap().dump().unwrap();
        let back = from_json(&Json::parse(&text).unwrap()).unwrap();
        let (a, b) = (model.as_dual().unwrap(), back.as_dual().unwrap());
        assert_eq!(a.dual_coef, b.dual_coef);
        assert_eq!(a.train_start_features.data(), b.train_start_features.data());
        assert_eq!(a.train_end_features.data(), b.train_end_features.data());
        assert_eq!(a.train_idx, b.train_idx);
        assert_eq!(a.kernel_d, b.kernel_d);
        assert_eq!(a.kernel_t, b.kernel_t);
        assert_eq!(a.pairwise, b.pairwise);
        assert_eq!(model.lambda().to_bits(), back.lambda().to_bits());
    }

    #[test]
    fn primal_document_round_trips_bitwise() {
        let mut rng = Pcg32::seeded(51);
        let primal = PrimalModel { w: rng.normal_vec(6), d_features: 3, r_features: 2 };
        let model = TrainedModel::from_primal(primal, 0.5);
        let text = to_json(&model).unwrap().dump().unwrap();
        let back = from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(model.as_primal().unwrap().w, back.as_primal().unwrap().w);
        assert_eq!(back.as_primal().unwrap().d_features, 3);
    }

    #[test]
    fn non_finite_parameters_refuse_to_save() {
        let mut model = toy_dual(52);
        if let ModelInner::Dual(d) = &mut model.inner {
            d.dual_coef[3] = f64::NAN;
        }
        let err = to_json(&model).unwrap_err();
        assert!(err.contains("dual_coef[3]"), "{err}");
    }

    #[test]
    fn version_and_schema_violations_are_rejected() {
        let model = toy_dual(53);
        let good = to_json(&model).unwrap();
        // over-versioned
        let mut doc = good.as_obj().unwrap().clone();
        doc.insert("format".into(), Json::from("kronvt-model/v3"));
        let err = from_json(&Json::Obj(doc)).unwrap_err();
        assert!(err.contains("kronvt-model/v3") && err.contains("kronvt-model/v2"), "{err}");
        // not an artifact at all
        assert!(from_json(&Json::parse("{}").unwrap()).is_err());
        // out-of-bounds edge index
        let mut doc = good.as_obj().unwrap().clone();
        let mut idx = doc["train_idx"].as_obj().unwrap().clone();
        idx.insert("left".into(), {
            let mut left = doc["train_idx"].get("left").unwrap().as_arr().unwrap().to_vec();
            left[0] = Json::from(999usize);
            Json::Arr(left)
        });
        doc.insert("train_idx".into(), Json::Obj(idx));
        assert!(from_json(&Json::Obj(doc)).is_err());
        // coefficient / edge count mismatch
        let mut doc = good.as_obj().unwrap().clone();
        doc.insert("dual_coef".into(), Json::num_arr(&[1.0, 2.0]));
        let err = from_json(&Json::Obj(doc)).unwrap_err();
        assert!(err.contains("dual_coef"), "{err}");
    }

    #[test]
    fn non_finite_values_are_rejected_on_load() {
        let model = toy_dual(55);
        let good = to_json(&model).unwrap();
        // 1e999 passes the JSON number grammar but parses to +inf — the
        // schema checks alone would let it through.
        let mut doc = good.as_obj().unwrap().clone();
        let mut coef = doc["dual_coef"].as_arr().unwrap().to_vec();
        coef[0] = Json::parse("1e999").unwrap();
        doc.insert("dual_coef".into(), Json::Arr(coef));
        let err = from_json(&Json::Obj(doc)).unwrap_err();
        assert!(err.contains("dual_coef"), "{err}");
        // NaN kernel hyperparameter ("gaussian:NaN" parses)
        let mut doc = good.as_obj().unwrap().clone();
        doc.insert("kernel_d".into(), Json::from("gaussian:NaN"));
        assert!(from_json(&Json::Obj(doc)).is_err());
        // non-finite lambda
        let mut doc = good.as_obj().unwrap().clone();
        doc.insert("lambda".into(), Json::parse("-1e999").unwrap());
        assert!(from_json(&Json::Obj(doc)).is_err());
    }

    fn toy_tensor(seed: u64) -> TrainedModel {
        let mut rng = Pcg32::seeded(seed);
        let dims = [4usize, 3, 5];
        let n = 9;
        TrainedModel::from_tensor(
            TensorModel {
                dual_coef: rng.normal_vec(n),
                train_features: dims
                    .iter()
                    .map(|&d| Matrix::from_fn(d, 2, |_, _| rng.normal()))
                    .collect(),
                train_idx: TensorIndex::new(
                    dims.iter().map(|&d| (0..n).map(|_| rng.below(d) as u32).collect()).collect(),
                ),
                kernels: vec![
                    KernelKind::Gaussian { gamma: 0.25 },
                    KernelKind::Linear,
                    KernelKind::Gaussian { gamma: 1.5 },
                ],
            },
            2f64.powi(-5),
        )
    }

    #[test]
    fn tensor_document_round_trips_bitwise_under_v2() {
        let model = toy_tensor(60);
        let doc = to_json(&model).unwrap();
        assert_eq!(doc.get("format").unwrap().as_str(), Some(FORMAT_V2));
        assert_eq!(doc.get("kind").unwrap().as_str(), Some("tensor"));
        let text = doc.dump().unwrap();
        let back = from_json(&Json::parse(&text).unwrap()).unwrap();
        let (a, b) = (model.as_tensor().unwrap(), back.as_tensor().unwrap());
        assert_eq!(a.dual_coef, b.dual_coef);
        assert_eq!(a.train_idx, b.train_idx);
        assert_eq!(a.kernels, b.kernels);
        for (fa, fb) in a.train_features.iter().zip(&b.train_features) {
            assert_eq!(fa.data(), fb.data());
        }
        assert_eq!(model.lambda().to_bits(), back.lambda().to_bits());
        // dual / primal keep writing v1, so pre-tensor readers still work
        assert_eq!(to_json(&toy_dual(61)).unwrap().get("format").unwrap().as_str(), Some(FORMAT));
    }

    #[test]
    fn corrupt_tensor_documents_are_rejected() {
        let good = to_json(&toy_tensor(62)).unwrap();
        // ragged index columns
        let mut doc = good.as_obj().unwrap().clone();
        let mut idx = doc["train_idx"].as_obj().unwrap().clone();
        let mut modes = idx["modes"].as_arr().unwrap().to_vec();
        let mut col0 = modes[0].as_arr().unwrap().to_vec();
        col0.pop();
        modes[0] = Json::Arr(col0);
        idx.insert("modes".into(), Json::Arr(modes));
        doc.insert("train_idx".into(), Json::Obj(idx));
        let err = from_json(&Json::Obj(doc)).unwrap_err();
        assert!(err.contains("mode 0"), "{err}");
        // out-of-bounds vertex index
        let mut doc = good.as_obj().unwrap().clone();
        let mut idx = doc["train_idx"].as_obj().unwrap().clone();
        let mut modes = idx["modes"].as_arr().unwrap().to_vec();
        let mut col1 = modes[1].as_arr().unwrap().to_vec();
        col1[0] = Json::from(999usize);
        modes[1] = Json::Arr(col1);
        idx.insert("modes".into(), Json::Arr(modes));
        doc.insert("train_idx".into(), Json::Obj(idx));
        assert!(from_json(&Json::Obj(doc)).is_err());
        // kernel count / mode count mismatch
        let mut doc = good.as_obj().unwrap().clone();
        let mut kernels = doc["mode_kernels"].as_arr().unwrap().to_vec();
        kernels.pop();
        doc.insert("mode_kernels".into(), Json::Arr(kernels));
        let err = from_json(&Json::Obj(doc)).unwrap_err();
        assert!(err.contains("mode kernels"), "{err}");
        // non-finite dual coefficient smuggled through the number grammar
        let mut doc = good.as_obj().unwrap().clone();
        let mut coef = doc["dual_coef"].as_arr().unwrap().to_vec();
        coef[0] = Json::parse("1e999").unwrap();
        doc.insert("dual_coef".into(), Json::Arr(coef));
        assert!(from_json(&Json::Obj(doc)).is_err());
    }

    #[test]
    fn trace_survives_with_non_finite_entries_nulled() {
        let mut trace = TrainTrace::default();
        trace.push(IterRecord { iter: 1, risk: 2.5, val_auc: Some(0.75), elapsed_secs: 0.1 });
        trace.push(IterRecord { iter: 2, risk: f64::NAN, val_auc: None, elapsed_secs: 0.2 });
        let model = toy_dual(54).with_trace(trace);
        let text = to_json(&model).unwrap().dump().unwrap();
        let back = from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.trace().records.len(), 2);
        assert_eq!(back.trace().records[0].risk, 2.5);
        assert_eq!(back.trace().records[0].val_auc, Some(0.75));
        assert!(back.trace().records[1].risk.is_nan(), "nulled risk loads as NaN");
    }
}
