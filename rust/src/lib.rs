//! # kronvt — Fast Kronecker product kernel methods via the generalized vec trick
//!
//! Rust implementation of Airola & Pahikkala, *"Fast Kronecker product kernel
//! methods via generalized vec trick"* (stat.ML 2016 / IEEE TNNLS 2017).
//!
//! The library learns supervised models over labeled bipartite graphs
//! `(d_i, t_j, y_h)` where start vertices `d` and end vertices `t` each carry
//! their own feature representation, and the edge kernel is the Kronecker
//! (product) kernel `k⊗((d,t),(d',t')) = k(d,d')·g(t,t')`.  The central
//! computational primitive is the **generalized vec trick** ([`gvt`]):
//!
//! ```text
//! u = R (M ⊗ N) Cᵀ v      computed in O(min(ae + df, ce + bf))
//! ```
//!
//! without materializing the Kronecker product, where `R`/`C` are row/column
//! index matrices selecting the edges that actually occur in the (sparse,
//! non-complete) training graph. The [`gvt::GvtEngine`] shards that matvec
//! across cores with bitwise-deterministic results; the [`api::Compute`]
//! execution policy exposes it uniformly to every trainer and the serving
//! pipeline (see the quickstart below). The same apply composes into a whole
//! **pairwise kernel family** — symmetric, anti-symmetric, and Cartesian
//! kernels for homogeneous graphs and ranking ([`gvt::PairwiseOp`],
//! `pairwise(…)` on every trainer / the [`api::Learner`] builder).
//!
//! ## Architecture (three layers)
//!
//! * **Layer 3 (this crate)** — the full learning framework: kernels, losses,
//!   truncated-Newton training ([`train`]), ridge regression and SVM case
//!   studies, baselines, data generators, evaluation, and a batched zero-shot
//!   prediction coordinator ([`coordinator`]).
//! * **Layer 2 (build-time JAX)** — dense-path compute graphs AOT-lowered to
//!   HLO text under `artifacts/`, loaded by [`runtime`] via PJRT.
//! * **Layer 1 (build-time Pallas)** — MXU-tiled matmul / pairwise-distance
//!   kernels inside the L2 graphs.
//!
//! Python never runs at training or serving time; the [`coordinator::Router`]
//! picks per-operation between the native Rust GVT loops (sparse graphs) and
//! the PJRT dense-matmul artifacts (dense-ish graphs).
//!
//! ## Quickstart
//!
//! One builder-based lifecycle — **fit → save → load → serve** — covers
//! every trainer ([`api`]). This example runs as a doc test (`cargo test
//! --doc`):
//!
//! ```
//! use kronvt::api::{Compute, Learner, TrainedModel};
//! use kronvt::data::checkerboard::CheckerboardConfig;
//! use kronvt::eval::auc::auc;
//! use kronvt::kernels::KernelKind;
//!
//! let data = CheckerboardConfig { m: 90, q: 90, density: 0.25, noise: 0.2, feature_range: 20.0, seed: 42 }
//!     .generate();
//! let (train, test) = data.zero_shot_split(0.25, 7);
//!
//! // fit: the fluent Learner builder over ridge / SVM / Newton trainers.
//! let model = Learner::ridge()
//!     .lambda(2f64.powi(-7))
//!     .kernel(KernelKind::Gaussian { gamma: 1.0 })
//!     .iterations(100)
//!     .compute(Compute::threads(2)) // shard every GVT matvec; bitwise-identical results
//!     .fit(&train)
//!     .unwrap();
//! let scores = model.predict(&test);
//! assert!(auc(&test.labels, &scores) > 0.6, "zero-shot AUC beats chance comfortably");
//!
//! // save → load: the portable `kronvt-model/v1` artifact predicts
//! // bitwise-identically in a fresh process (`kronvt predict`, `kronvt
//! // serve --model`).
//! let path = std::env::temp_dir().join(format!("kronvt_doc_{}.json", std::process::id()));
//! model.save(&path).unwrap();
//! let loaded = TrainedModel::load(&path).unwrap();
//! std::fs::remove_file(&path).ok();
//! assert_eq!(loaded.predict(&test), scores);
//! ```
//!
//! To serve that artifact over TCP instead of in-process, see
//! [`coordinator::net`] and `docs/SERVING.md`; the module map from paper
//! equations to code lives in `docs/ARCHITECTURE.md`.

#![warn(missing_docs)]

pub mod util;
pub mod linalg;
pub mod gvt;
pub mod kernels;
pub mod losses;
pub mod model;
pub mod train;
pub mod api;
pub mod baselines;
pub mod data;
pub mod eval;
pub mod runtime;
pub mod coordinator;
