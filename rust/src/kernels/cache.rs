//! Content-hashed per-vertex kernel-row cache for the serving pipeline.
//!
//! Serving traffic for drug–target and collaborative-filtering workloads
//! repeats vertices across requests far more often than it repeats whole
//! requests, so the cache sits in front of the test–train kernel blocks
//! `K̂` / `Ĝ` at *vertex* granularity: the key is the vertex's feature vector
//! (by content — the exact `f64` bit patterns), the value is its kernel row
//! against the training vertices. Rows are produced by
//! [`kernel_row_into`](super::compute::kernel_row_into), which is bitwise
//! identical to the corresponding [`kernel_matrix`](super::kernel_matrix)
//! row, so mixing cached and freshly computed rows cannot perturb scores.
//!
//! The cache is a bounded LRU (intrusive doubly-linked list over a slab, so
//! touch and evict are O(1)) behind a [`Mutex`]; hit/miss counters are
//! atomics shared with the owner (the server surfaces them in
//! `ServerStats`). Lookups clone out an [`Arc`] of the row, so the lock is
//! never held while a caller computes a missing row.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

/// Sentinel for "no neighbor" in the intrusive LRU list.
const NIL: usize = usize::MAX;

/// Cache key: the vertex's feature vector by content. Comparing the raw bit
/// patterns (rather than `f64` values) keeps `Eq`/`Hash` total — two NaN
/// features with the same payload are the same vertex, `0.0` and `-0.0` are
/// distinct — and guarantees a hit returns a row computed from *identical*
/// input bits.
#[derive(Clone, PartialEq, Eq, Hash)]
struct FeatureKey(Box<[u64]>);

impl FeatureKey {
    fn new(features: &[f64]) -> FeatureKey {
        FeatureKey(features.iter().map(|f| f.to_bits()).collect())
    }
}

/// One slab entry: the key (kept for removal on eviction), the cached kernel
/// row, and the intrusive list links (`prev` is toward the MRU end).
struct Slot {
    key: FeatureKey,
    row: Arc<[f64]>,
    prev: usize,
    next: usize,
}

/// Map + slab + list head/tail, all guarded by one lock.
struct LruInner {
    map: HashMap<FeatureKey, usize>,
    slots: Vec<Slot>,
    /// Slab indices available for reuse after eviction.
    free: Vec<usize>,
    /// Most recently used slot (NIL when empty).
    head: usize,
    /// Least recently used slot (NIL when empty).
    tail: usize,
}

impl LruInner {
    /// Unlink `i` from the list (it must be linked).
    fn unlink(&mut self, i: usize) {
        let (prev, next) = (self.slots[i].prev, self.slots[i].next);
        if prev == NIL {
            self.head = next;
        } else {
            self.slots[prev].next = next;
        }
        if next == NIL {
            self.tail = prev;
        } else {
            self.slots[next].prev = prev;
        }
    }

    /// Link `i` at the MRU end.
    fn push_front(&mut self, i: usize) {
        self.slots[i].prev = NIL;
        self.slots[i].next = self.head;
        if self.head != NIL {
            self.slots[self.head].prev = i;
        }
        self.head = i;
        if self.tail == NIL {
            self.tail = i;
        }
    }
}

/// Bounded LRU cache of per-vertex kernel rows, keyed by feature content.
///
/// Thread-safe: lookups and inserts take an internal lock only long enough to
/// touch the index; the row itself is shared via [`Arc`], and a missing row
/// is computed by the caller *outside* the lock (two racing misses both
/// compute the row — harmless, the values are identical by construction).
pub struct KernelRowCache {
    capacity: usize,
    inner: Mutex<LruInner>,
    hits: Arc<AtomicUsize>,
    misses: Arc<AtomicUsize>,
}

impl std::fmt::Debug for KernelRowCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("KernelRowCache")
            .field("capacity", &self.capacity)
            .field("len", &self.len())
            .field("hits", &self.hits())
            .field("misses", &self.misses())
            .finish()
    }
}

impl KernelRowCache {
    /// Cache holding at most `capacity` vertex rows (`0` caches nothing —
    /// every lookup misses).
    pub fn new(capacity: usize) -> KernelRowCache {
        KernelRowCache::with_counters(
            capacity,
            Arc::new(AtomicUsize::new(0)),
            Arc::new(AtomicUsize::new(0)),
        )
    }

    /// Like [`KernelRowCache::new`], but incrementing externally owned
    /// hit/miss counters (the server passes its `ServerStats` fields so both
    /// per-side caches aggregate into one pair).
    pub fn with_counters(
        capacity: usize,
        hits: Arc<AtomicUsize>,
        misses: Arc<AtomicUsize>,
    ) -> KernelRowCache {
        KernelRowCache {
            capacity,
            inner: Mutex::new(LruInner {
                map: HashMap::new(),
                slots: Vec::new(),
                free: Vec::new(),
                head: NIL,
                tail: NIL,
            }),
            hits,
            misses,
        }
    }

    /// Maximum number of cached rows.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Number of rows currently cached.
    pub fn len(&self) -> usize {
        self.lock().map.len()
    }

    /// Whether the cache currently holds no rows.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cache hits so far.
    pub fn hits(&self) -> usize {
        self.hits.load(Ordering::Relaxed)
    }

    /// Cache misses so far.
    pub fn misses(&self) -> usize {
        self.misses.load(Ordering::Relaxed)
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, LruInner> {
        self.inner.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
    }

    /// Look up the cached row for `features`, marking it most recently used.
    /// Counts a hit or a miss.
    pub fn lookup(&self, features: &[f64]) -> Option<Arc<[f64]>> {
        if self.capacity == 0 {
            self.misses.fetch_add(1, Ordering::Relaxed);
            return None;
        }
        let key = FeatureKey::new(features);
        let mut inner = self.lock();
        if let Some(&i) = inner.map.get(&key) {
            inner.unlink(i);
            inner.push_front(i);
            self.hits.fetch_add(1, Ordering::Relaxed);
            Some(inner.slots[i].row.clone())
        } else {
            self.misses.fetch_add(1, Ordering::Relaxed);
            None
        }
    }

    /// Insert a freshly computed row, evicting the least recently used entry
    /// if the cache is full. If another thread inserted the same key in the
    /// meantime, the existing row wins (the values are identical anyway).
    pub fn insert(&self, features: &[f64], row: Arc<[f64]>) {
        if self.capacity == 0 {
            return;
        }
        let key = FeatureKey::new(features);
        let mut inner = self.lock();
        if inner.map.contains_key(&key) {
            return;
        }
        if inner.map.len() >= self.capacity {
            let lru = inner.tail;
            debug_assert_ne!(lru, NIL);
            inner.unlink(lru);
            let old_key = inner.slots[lru].key.clone();
            inner.map.remove(&old_key);
            inner.free.push(lru);
        }
        let slot = Slot { key: key.clone(), row, prev: NIL, next: NIL };
        let i = match inner.free.pop() {
            Some(i) => {
                inner.slots[i] = slot;
                i
            }
            None => {
                inner.slots.push(slot);
                inner.slots.len() - 1
            }
        };
        inner.push_front(i);
        inner.map.insert(key, i);
    }

    /// Convenience: [`KernelRowCache::lookup`] or compute-and-[`insert`]
    /// (`compute` fills the row; it runs without holding the cache lock).
    ///
    /// [`insert`]: KernelRowCache::insert
    pub fn get_or_compute(
        &self,
        features: &[f64],
        row_len: usize,
        compute: impl FnOnce(&mut [f64]),
    ) -> Arc<[f64]> {
        if let Some(row) = self.lookup(features) {
            return row;
        }
        let mut row = vec![0.0; row_len];
        compute(&mut row);
        let row: Arc<[f64]> = row.into();
        self.insert(features, row.clone());
        row
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(v: f64, n: usize) -> Arc<[f64]> {
        vec![v; n].into()
    }

    #[test]
    fn lookup_after_insert_hits() {
        let cache = KernelRowCache::new(4);
        assert!(cache.lookup(&[1.0, 2.0]).is_none());
        cache.insert(&[1.0, 2.0], row(7.0, 3));
        let got = cache.lookup(&[1.0, 2.0]).expect("hit");
        assert_eq!(&got[..], &[7.0; 3]);
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn keys_are_content_hashed() {
        let cache = KernelRowCache::new(4);
        cache.insert(&[1.0, 2.0], row(1.0, 2));
        // equal content, different allocation: still a hit
        let same = [1.0, 2.0];
        assert!(cache.lookup(&same).is_some());
        // different content misses; -0.0 is a distinct bit pattern from 0.0
        assert!(cache.lookup(&[1.0, 2.5]).is_none());
        cache.insert(&[0.0], row(2.0, 1));
        assert!(cache.lookup(&[-0.0]).is_none());
    }

    #[test]
    fn evicts_least_recently_used() {
        let cache = KernelRowCache::new(2);
        cache.insert(&[1.0], row(1.0, 1));
        cache.insert(&[2.0], row(2.0, 1));
        // touch [1.0] so [2.0] becomes the LRU entry
        assert!(cache.lookup(&[1.0]).is_some());
        cache.insert(&[3.0], row(3.0, 1));
        assert_eq!(cache.len(), 2);
        assert!(cache.lookup(&[2.0]).is_none(), "LRU entry must be evicted");
        assert!(cache.lookup(&[1.0]).is_some());
        assert!(cache.lookup(&[3.0]).is_some());
    }

    #[test]
    fn eviction_churn_keeps_exactly_capacity() {
        let cache = KernelRowCache::new(3);
        for i in 0..20 {
            cache.insert(&[i as f64], row(i as f64, 2));
            assert!(cache.len() <= 3);
        }
        assert_eq!(cache.len(), 3);
        // the last three inserted survive, in MRU order 19, 18, 17
        for i in 17..20 {
            let got = cache.lookup(&[i as f64]).expect("recent entry cached");
            assert_eq!(got[0], i as f64);
        }
        assert!(cache.lookup(&[16.0]).is_none());
    }

    #[test]
    fn zero_capacity_disables_caching() {
        let cache = KernelRowCache::new(0);
        cache.insert(&[1.0], row(1.0, 1));
        assert!(cache.lookup(&[1.0]).is_none());
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn get_or_compute_computes_once() {
        let cache = KernelRowCache::new(4);
        let mut calls = 0;
        for _ in 0..3 {
            let got = cache.get_or_compute(&[5.0, 6.0], 2, |out| {
                calls += 1;
                out.copy_from_slice(&[5.0, 6.0]);
            });
            assert_eq!(&got[..], &[5.0, 6.0]);
        }
        assert_eq!(calls, 1);
        assert_eq!(cache.hits(), 2);
        assert_eq!(cache.misses(), 1);
    }

    #[test]
    fn shared_counters_aggregate() {
        let hits = Arc::new(AtomicUsize::new(0));
        let misses = Arc::new(AtomicUsize::new(0));
        let a = KernelRowCache::with_counters(2, hits.clone(), misses.clone());
        let b = KernelRowCache::with_counters(2, hits.clone(), misses.clone());
        a.insert(&[1.0], row(1.0, 1));
        b.insert(&[2.0], row(2.0, 1));
        a.lookup(&[1.0]);
        b.lookup(&[2.0]);
        b.lookup(&[9.0]);
        assert_eq!(hits.load(Ordering::Relaxed), 2);
        assert_eq!(misses.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn concurrent_access_is_consistent() {
        let cache = Arc::new(KernelRowCache::new(8));
        std::thread::scope(|scope| {
            for t in 0..4 {
                let cache = Arc::clone(&cache);
                scope.spawn(move || {
                    for i in 0..50 {
                        let feat = [(i % 10) as f64, t as f64 % 2.0];
                        let got = cache.get_or_compute(&feat, 2, |out| {
                            out.copy_from_slice(&feat);
                        });
                        assert_eq!(&got[..], &feat);
                    }
                });
            }
        });
        assert!(cache.len() <= 8);
        assert_eq!(cache.hits() + cache.misses(), 200);
    }
}
