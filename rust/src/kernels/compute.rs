//! Kernel matrix computation.
//!
//! Kernel matrices sit under every training setup, CV fold, and serving
//! batch, so they are computed blockwise from the Gram matrix:
//! `‖x−y‖² = ‖x‖² + ‖y‖² − 2⟨x,y⟩`, with the inner-product matrix produced
//! by the packed, register-blocked GEMM in [`crate::linalg::gemm`]
//! (`Matrix::matmul_nt`, optionally sharded across threads via
//! [`kernel_matrix_threaded`]; this mirrors the L1 Pallas `pairwise.py`
//! kernel). Every GEMM element is bitwise identical to
//! `dot(x1.row(i), x2.row(j))`, for any thread count — which is exactly what
//! [`kernel_row_into`] computes, so single rows, full matrices, serial and
//! threaded builds all agree bit-for-bit.

use super::KernelKind;
use crate::linalg::vecops::dot;
use crate::linalg::Matrix;

/// Single kernel evaluation `k(x, y)`.
pub fn kernel_value(kind: KernelKind, x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len(), "feature dim mismatch");
    match kind {
        KernelKind::Linear => dot(x, y),
        KernelKind::Gaussian { gamma } => {
            let mut sq = 0.0;
            for (xi, yi) in x.iter().zip(y) {
                let d = xi - yi;
                sq += d * d;
            }
            (-gamma * sq).exp()
        }
        KernelKind::Polynomial { gamma, coef0, degree } => {
            (gamma * dot(x, y) + coef0).powi(degree as i32)
        }
        KernelKind::Tanimoto => {
            let xy = dot(x, y);
            let denom = dot(x, x) + dot(y, y) - xy;
            if denom <= 0.0 {
                0.0
            } else {
                xy / denom
            }
        }
    }
}

/// Squared Euclidean norms of every row of `x` — the per-vertex
/// precomputation shared by [`kernel_matrix`] and [`kernel_row_into`].
pub fn row_sq_norms(x: &Matrix) -> Vec<f64> {
    (0..x.rows()).map(|i| dot(x.row(i), x.row(i))).collect()
}

/// One kernel-matrix row `out[j] = k(x, x2_j)` against every row of `x2`.
///
/// `sq2` must be [`row_sq_norms`]`(x2)` (it is only read by the Gaussian and
/// Tanimoto kernels, but callers should always pass it so the signature stays
/// kernel-agnostic). The result is **bitwise identical** to the corresponding
/// row of [`kernel_matrix`]: both compute each entry from the same
/// [`dot`]-product and apply the same scalar formula in the same order, and
/// `matmul_nt` evaluates output rows independently. This is what lets the
/// serving-side per-vertex row cache ([`super::cache::KernelRowCache`]) mix
/// cached and freshly computed rows without perturbing scores.
pub fn kernel_row_into(kind: KernelKind, x: &[f64], x2: &Matrix, sq2: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), x2.cols(), "feature dim mismatch");
    assert_eq!(out.len(), x2.rows(), "output length mismatch");
    debug_assert_eq!(sq2.len(), x2.rows());
    match kind {
        KernelKind::Linear => {
            for (j, o) in out.iter_mut().enumerate() {
                *o = dot(x, x2.row(j));
            }
        }
        KernelKind::Gaussian { gamma } => {
            let si = dot(x, x);
            for (j, o) in out.iter_mut().enumerate() {
                let ip = dot(x, x2.row(j));
                // clamp tiny negative round-off in the squared distance
                let d2 = (si + sq2[j] - 2.0 * ip).max(0.0);
                *o = (-gamma * d2).exp();
            }
        }
        KernelKind::Polynomial { gamma, coef0, degree } => {
            for (j, o) in out.iter_mut().enumerate() {
                *o = (gamma * dot(x, x2.row(j)) + coef0).powi(degree as i32);
            }
        }
        KernelKind::Tanimoto => {
            let si = dot(x, x);
            for (j, o) in out.iter_mut().enumerate() {
                let ip = dot(x, x2.row(j));
                let denom = si + sq2[j] - ip;
                *o = if denom <= 0.0 { 0.0 } else { ip / denom };
            }
        }
    }
}

/// Kernel matrix `K[i,j] = k(x1_i, x2_j)` for row-feature matrices.
pub fn kernel_matrix(kind: KernelKind, x1: &Matrix, x2: &Matrix) -> Matrix {
    kernel_matrix_threaded(kind, x1, x2, 1)
}

/// [`kernel_matrix`] with the inner-product GEMM sharded over `threads`
/// worker threads (`0` = all cores, `1` = serial). The result is bitwise
/// identical for every thread count, so training setup and CV folds can use
/// all cores without perturbing solver trajectories.
pub fn kernel_matrix_threaded(
    kind: KernelKind,
    x1: &Matrix,
    x2: &Matrix,
    threads: usize,
) -> Matrix {
    assert_eq!(x1.cols(), x2.cols(), "feature dim mismatch");
    match kind {
        KernelKind::Linear => x1.matmul_nt_threaded(x2, threads),
        KernelKind::Gaussian { gamma } => {
            let mut k = x1.matmul_nt_threaded(x2, threads); // inner products
            let n1 = x1.rows();
            let n2 = x2.rows();
            let sq1 = row_sq_norms(x1);
            let sq2 = row_sq_norms(x2);
            for i in 0..n1 {
                let row = k.row_mut(i);
                let si = sq1[i];
                for j in 0..n2 {
                    // clamp tiny negative round-off in the squared distance
                    let d2 = (si + sq2[j] - 2.0 * row[j]).max(0.0);
                    row[j] = (-gamma * d2).exp();
                }
            }
            k
        }
        KernelKind::Polynomial { gamma, coef0, degree } => {
            let mut k = x1.matmul_nt_threaded(x2, threads);
            k.data_mut().iter_mut().for_each(|v| *v = (gamma * *v + coef0).powi(degree as i32));
            k
        }
        KernelKind::Tanimoto => {
            let mut k = x1.matmul_nt_threaded(x2, threads);
            let n1 = x1.rows();
            let n2 = x2.rows();
            let sq1 = row_sq_norms(x1);
            let sq2 = row_sq_norms(x2);
            for i in 0..n1 {
                let row = k.row_mut(i);
                for j in 0..n2 {
                    let denom = sq1[i] + sq2[j] - row[j];
                    row[j] = if denom <= 0.0 { 0.0 } else { row[j] / denom };
                }
            }
            k
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest;
    use crate::util::rng::Pcg32;

    fn random_features(rng: &mut Pcg32, n: usize, d: usize) -> Matrix {
        Matrix::from_fn(n, d, |_, _| rng.normal())
    }

    #[test]
    fn matrix_matches_pairwise_values() {
        proptest::check_n(0xFEED, 8, |rng| {
            let n1 = 1 + rng.below(6);
            let n2 = 1 + rng.below(6);
            let d = 1 + rng.below(5);
            let x1 = random_features(rng, n1, d);
            let x2 = random_features(rng, n2, d);
            for kind in [
                KernelKind::Linear,
                KernelKind::Gaussian { gamma: 0.3 },
                KernelKind::Polynomial { gamma: 0.5, coef0: 1.0, degree: 3 },
            ] {
                let k = kernel_matrix(kind, &x1, &x2);
                for i in 0..n1 {
                    for j in 0..n2 {
                        let v = kernel_value(kind, x1.row(i), x2.row(j));
                        assert!(
                            (k.get(i, j) - v).abs() < 1e-9,
                            "{kind:?} ({i},{j}): {} vs {v}",
                            k.get(i, j)
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn kernel_row_is_bitwise_identical_to_matrix_row() {
        // The serving-side vertex cache depends on this exact equality: a row
        // computed in isolation must match the row `kernel_matrix` produces.
        proptest::check_n(0xCA5E, 8, |rng| {
            let n1 = 1 + rng.below(5);
            let n2 = 1 + rng.below(7);
            let d = 1 + rng.below(6);
            let x1 = random_features(rng, n1, d);
            let x2 = random_features(rng, n2, d);
            let sq2 = row_sq_norms(&x2);
            for kind in [
                KernelKind::Linear,
                KernelKind::Gaussian { gamma: 0.7 },
                KernelKind::Polynomial { gamma: 0.5, coef0: 1.0, degree: 3 },
                KernelKind::Tanimoto,
            ] {
                let k = kernel_matrix(kind, &x1, &x2);
                let mut row = vec![0.0; n2];
                for i in 0..n1 {
                    kernel_row_into(kind, x1.row(i), &x2, &sq2, &mut row);
                    assert_eq!(row.as_slice(), k.row(i), "{kind:?} row {i}");
                }
            }
        });
    }

    #[test]
    fn threaded_kernel_matrix_matches_serial_bitwise() {
        let mut rng = Pcg32::seeded(95);
        let x1 = random_features(&mut rng, 23, 7);
        let x2 = random_features(&mut rng, 31, 7);
        for kind in [
            KernelKind::Linear,
            KernelKind::Gaussian { gamma: 0.6 },
            KernelKind::Tanimoto,
        ] {
            let serial = kernel_matrix(kind, &x1, &x2);
            for threads in [2, 4] {
                let par = kernel_matrix_threaded(kind, &x1, &x2, threads);
                assert_eq!(par, serial, "{kind:?} threads={threads}");
            }
        }
    }

    #[test]
    fn gaussian_diagonal_is_one() {
        let mut rng = Pcg32::seeded(91);
        let x = random_features(&mut rng, 10, 4);
        let k = kernel_matrix(KernelKind::Gaussian { gamma: 2.0 }, &x, &x);
        for i in 0..10 {
            assert!((k.get(i, i) - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn gaussian_is_psd() {
        // Gram matrix + tiny jitter should be Cholesky-factorizable.
        let mut rng = Pcg32::seeded(92);
        let x = random_features(&mut rng, 15, 3);
        let mut k = KernelKind::Gaussian { gamma: 0.5 }.square_matrix(&x);
        k.add_diag(1e-9);
        assert!(k.cholesky().is_some());
    }

    #[test]
    fn tanimoto_on_binary_features() {
        let x1 = Matrix::from_rows(&[&[1.0, 1.0, 0.0, 0.0]]);
        let x2 = Matrix::from_rows(&[&[1.0, 0.0, 1.0, 0.0]]);
        // |intersection| = 1, |union| = 3
        let k = kernel_matrix(KernelKind::Tanimoto, &x1, &x2);
        assert!((k.get(0, 0) - 1.0 / 3.0).abs() < 1e-12);
        // self-similarity = 1
        let kself = kernel_matrix(KernelKind::Tanimoto, &x1, &x1);
        assert!((kself.get(0, 0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn gaussian_kron_equals_concat_gaussian() {
        // The LibSVM-comparison identity from §5.1: with equal widths,
        // k(d,d')·g(t,t') = gaussian on concatenated features [d,t].
        let mut rng = Pcg32::seeded(93);
        let gamma = 0.7;
        let d1 = rng.normal_vec(3);
        let d2 = rng.normal_vec(3);
        let t1 = rng.normal_vec(2);
        let t2 = rng.normal_vec(2);
        let prod = kernel_value(KernelKind::Gaussian { gamma }, &d1, &d2)
            * kernel_value(KernelKind::Gaussian { gamma }, &t1, &t2);
        let mut c1 = d1.clone();
        c1.extend_from_slice(&t1);
        let mut c2 = d2.clone();
        c2.extend_from_slice(&t2);
        let concat = kernel_value(KernelKind::Gaussian { gamma }, &c1, &c2);
        assert!((prod - concat).abs() < 1e-12);
    }

    #[test]
    fn square_matrix_is_exactly_symmetric() {
        let mut rng = Pcg32::seeded(94);
        let x = random_features(&mut rng, 20, 6);
        let k = KernelKind::Gaussian { gamma: 0.1 }.square_matrix(&x);
        for i in 0..20 {
            for j in 0..20 {
                assert_eq!(k.get(i, j), k.get(j, i));
            }
        }
    }
}
