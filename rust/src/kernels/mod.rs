//! Positive semi-definite kernel functions on vertex features.
//!
//! The paper's framework needs two base kernels — `k` on start vertices and
//! `g` on end vertices — whose product forms the Kronecker edge kernel
//! `k⊗((d,t),(d',t')) = k(d,d')·g(t,t')`. The experiments use the linear
//! kernel (drug–target data) and the Gaussian kernel (checkerboard, LibSVM
//! comparison); polynomial and Tanimoto are provided for completeness
//! (Tanimoto is the standard choice for chemical fingerprints, the kind of
//! feature the original Ki/GPCR/IC/E data carries).

pub mod cache;
pub mod compute;

pub use cache::KernelRowCache;
pub use compute::{
    kernel_matrix, kernel_matrix_threaded, kernel_row_into, kernel_value, row_sq_norms,
};

use crate::linalg::Matrix;

/// Kernel function selector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum KernelKind {
    /// `k(x,y) = ⟨x,y⟩`
    Linear,
    /// `k(x,y) = exp(-γ‖x−y‖²)`
    Gaussian { gamma: f64 },
    /// `k(x,y) = (γ⟨x,y⟩ + c₀)^degree`
    Polynomial { gamma: f64, coef0: f64, degree: u32 },
    /// `k(x,y) = ⟨x,y⟩ / (‖x‖² + ‖y‖² − ⟨x,y⟩)`; requires non-negative
    /// features (fingerprints). Defined as 0 when the denominator is 0.
    Tanimoto,
}

impl Default for KernelKind {
    fn default() -> Self {
        KernelKind::Linear
    }
}

impl KernelKind {
    /// Parse from CLI strings like `linear`, `gaussian:0.1`, `poly:1:0:2`,
    /// `tanimoto`.
    pub fn parse(s: &str) -> Result<KernelKind, String> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts[0] {
            "linear" => Ok(KernelKind::Linear),
            "gaussian" | "rbf" => {
                let gamma = parts
                    .get(1)
                    .map(|v| v.parse().map_err(|e| format!("bad gamma: {e}")))
                    .transpose()?
                    .unwrap_or(1.0);
                Ok(KernelKind::Gaussian { gamma })
            }
            "poly" | "polynomial" => {
                let gamma = parts.get(1).and_then(|v| v.parse().ok()).unwrap_or(1.0);
                let coef0 = parts.get(2).and_then(|v| v.parse().ok()).unwrap_or(0.0);
                let degree = parts.get(3).and_then(|v| v.parse().ok()).unwrap_or(2);
                Ok(KernelKind::Polynomial { gamma, coef0, degree })
            }
            "tanimoto" => Ok(KernelKind::Tanimoto),
            other => Err(format!("unknown kernel '{other}'")),
        }
    }

    /// Human-readable name for manifests and logs.
    pub fn name(&self) -> String {
        match self {
            KernelKind::Linear => "linear".to_string(),
            KernelKind::Gaussian { gamma } => format!("gaussian:{gamma}"),
            KernelKind::Polynomial { gamma, coef0, degree } => {
                format!("poly:{gamma}:{coef0}:{degree}")
            }
            KernelKind::Tanimoto => "tanimoto".to_string(),
        }
    }

    /// Kernel matrix between row-feature matrices `x1 (n1×d)`, `x2 (n2×d)`.
    pub fn matrix(&self, x1: &Matrix, x2: &Matrix) -> Matrix {
        kernel_matrix(*self, x1, x2)
    }

    /// Symmetric training kernel matrix of `x (n×d)` with exact symmetry.
    pub fn square_matrix(&self, x: &Matrix) -> Matrix {
        self.square_matrix_threaded(x, 1)
    }

    /// [`KernelKind::square_matrix`] with the inner-product GEMM sharded over
    /// `threads` worker threads (`0` = all cores); bitwise identical to the
    /// serial build for every thread count.
    pub fn square_matrix_threaded(&self, x: &Matrix, threads: usize) -> Matrix {
        let mut k = kernel_matrix_threaded(*self, x, x, threads);
        k.symmetrize();
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in ["linear", "gaussian:0.5", "poly:1:0.5:3", "tanimoto"] {
            let k = KernelKind::parse(s).unwrap();
            assert_eq!(KernelKind::parse(&k.name()).unwrap(), k);
        }
        assert!(KernelKind::parse("nope").is_err());
    }

    #[test]
    fn rbf_alias() {
        assert_eq!(KernelKind::parse("rbf:2").unwrap(), KernelKind::Gaussian { gamma: 2.0 });
    }
}
