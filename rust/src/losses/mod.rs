//! Loss functions of Table 2, with (sub)gradients and (generalized) Hessians
//! with respect to the prediction vector `p`.
//!
//! The truncated-Newton framework (§3.2–3.3) only touches a loss through
//! `value`, `gradient` and Hessian–vector products, so any [`Loss`] plugs
//! into both the dual and primal trainers. For univariate losses the Hessian
//! is diagonal; RankRLS overrides the Hessian–vector product with its
//! efficient decomposition `H = nI − 𝟙𝟙ᵀ` ([42]).

/// A convex loss `L(p, y)` over prediction and label vectors.
pub trait Loss: Send + Sync {
    /// Short name for CLI lookup and logs.
    fn name(&self) -> &'static str;

    /// Loss value.
    fn value(&self, p: &[f64], y: &[f64]) -> f64;

    /// (Sub)gradient `g = ∂L/∂p`, written into `g`.
    fn gradient(&self, p: &[f64], y: &[f64], g: &mut [f64]);

    /// Diagonal of the (generalized) Hessian `∂²L/∂p²`. For non-diagonal
    /// Hessians this is just the diagonal; use [`Loss::hessian_vec`] for
    /// products.
    fn hessian_diag(&self, p: &[f64], y: &[f64], h: &mut [f64]);

    /// Hessian–vector product `out = H·v`. Default: diagonal Hessian.
    fn hessian_vec(&self, p: &[f64], y: &[f64], v: &[f64], out: &mut [f64]) {
        let mut h = vec![0.0; p.len()];
        self.hessian_diag(p, y, &mut h);
        for i in 0..v.len() {
            out[i] = h[i] * v[i];
        }
    }

    /// Whether the Hessian is diagonal (enables the masked Newton-system
    /// shortcut used by the SVM trainer).
    fn diagonal_hessian(&self) -> bool {
        true
    }
}

/// Squared loss `½‖p − y‖²` (ridge regression / regularized least squares).
#[derive(Debug, Clone, Copy, Default)]
pub struct RidgeLoss;

impl Loss for RidgeLoss {
    fn name(&self) -> &'static str {
        "ridge"
    }

    fn value(&self, p: &[f64], y: &[f64]) -> f64 {
        0.5 * p.iter().zip(y).map(|(pi, yi)| (pi - yi) * (pi - yi)).sum::<f64>()
    }

    fn gradient(&self, p: &[f64], y: &[f64], g: &mut [f64]) {
        for i in 0..p.len() {
            g[i] = p[i] - y[i];
        }
    }

    fn hessian_diag(&self, p: &[f64], _y: &[f64], h: &mut [f64]) {
        h[..p.len()].fill(1.0);
    }
}

/// Hinge loss `Σ max(0, 1 − p·y)` (L1-SVM). Subdifferentiable only; its
/// generalized Hessian is zero, so it pairs with first-order methods.
#[derive(Debug, Clone, Copy, Default)]
pub struct L1SvmLoss;

impl Loss for L1SvmLoss {
    fn name(&self) -> &'static str {
        "l1svm"
    }

    fn value(&self, p: &[f64], y: &[f64]) -> f64 {
        p.iter().zip(y).map(|(pi, yi)| (1.0 - pi * yi).max(0.0)).sum()
    }

    fn gradient(&self, p: &[f64], y: &[f64], g: &mut [f64]) {
        for i in 0..p.len() {
            g[i] = if p[i] * y[i] < 1.0 { -y[i] } else { 0.0 };
        }
    }

    fn hessian_diag(&self, p: &[f64], _y: &[f64], h: &mut [f64]) {
        h[..p.len()].fill(0.0);
    }
}

/// Squared hinge `½ Σ max(0, 1 − p·y)²` (L2-SVM) — the paper's SVM case
/// study (§4.2). For `y ∈ {−1,1}`: `gᵢ = pᵢ − yᵢ` on the active set
/// `S = {i : pᵢ·yᵢ < 1}`, generalized Hessian `diag(1_S)`.
#[derive(Debug, Clone, Copy, Default)]
pub struct L2SvmLoss;

impl L2SvmLoss {
    /// Active-set mask `1[pᵢ·yᵢ < 1]`.
    pub fn active_mask(p: &[f64], y: &[f64]) -> Vec<f64> {
        p.iter().zip(y).map(|(pi, yi)| if pi * yi < 1.0 { 1.0 } else { 0.0 }).collect()
    }
}

impl Loss for L2SvmLoss {
    fn name(&self) -> &'static str {
        "l2svm"
    }

    fn value(&self, p: &[f64], y: &[f64]) -> f64 {
        0.5 * p
            .iter()
            .zip(y)
            .map(|(pi, yi)| {
                let m = (1.0 - pi * yi).max(0.0);
                m * m
            })
            .sum::<f64>()
    }

    fn gradient(&self, p: &[f64], y: &[f64], g: &mut [f64]) {
        for i in 0..p.len() {
            // -y(1-py) = p·y² - y = p - y for y ∈ {-1,1}
            g[i] = if p[i] * y[i] < 1.0 { p[i] - y[i] } else { 0.0 };
        }
    }

    fn hessian_diag(&self, p: &[f64], y: &[f64], h: &mut [f64]) {
        for i in 0..p.len() {
            h[i] = if p[i] * y[i] < 1.0 { 1.0 } else { 0.0 };
        }
    }
}

/// Logistic loss `Σ log(1 + e^{−y·p})`.
#[derive(Debug, Clone, Copy, Default)]
pub struct LogisticLoss;

impl Loss for LogisticLoss {
    fn name(&self) -> &'static str {
        "logistic"
    }

    fn value(&self, p: &[f64], y: &[f64]) -> f64 {
        p.iter()
            .zip(y)
            .map(|(pi, yi)| {
                let z = -yi * pi;
                // numerically stable log(1+e^z)
                if z > 0.0 {
                    z + (1.0 + (-z).exp()).ln()
                } else {
                    (1.0 + z.exp()).ln()
                }
            })
            .sum()
    }

    fn gradient(&self, p: &[f64], y: &[f64], g: &mut [f64]) {
        for i in 0..p.len() {
            g[i] = -y[i] / (1.0 + (y[i] * p[i]).exp());
        }
    }

    fn hessian_diag(&self, p: &[f64], y: &[f64], h: &mut [f64]) {
        for i in 0..p.len() {
            let e = (y[i] * p[i]).exp();
            let d = 1.0 + e;
            h[i] = e / (d * d);
        }
    }
}

/// RankRLS / magnitude-preserving pairwise ranking loss ([42]):
/// `L = ¼ Σᵢ Σⱼ (yᵢ − pᵢ − yⱼ + pⱼ)²`. The Hessian is `n·I − 𝟙𝟙ᵀ`, so
/// Hessian–vector products cost `O(n)`.
#[derive(Debug, Clone, Copy, Default)]
pub struct RankRlsLoss;

impl Loss for RankRlsLoss {
    fn name(&self) -> &'static str {
        "rankrls"
    }

    fn value(&self, p: &[f64], y: &[f64]) -> f64 {
        // ¼ ΣᵢΣⱼ (eᵢ − eⱼ)² = ½ (n Σe² − (Σe)²) with e = y − p
        let n = p.len() as f64;
        let (mut se, mut se2) = (0.0, 0.0);
        for (pi, yi) in p.iter().zip(y) {
            let e = yi - pi;
            se += e;
            se2 += e * e;
        }
        0.5 * (n * se2 - se * se)
    }

    fn gradient(&self, p: &[f64], y: &[f64], g: &mut [f64]) {
        // gᵢ = Σⱼ(yⱼ − pⱼ) + n(pᵢ − yᵢ)   (Table 2)
        let n = p.len() as f64;
        let se: f64 = p.iter().zip(y).map(|(pi, yi)| yi - pi).sum();
        for i in 0..p.len() {
            g[i] = se + n * (p[i] - y[i]);
        }
    }

    fn hessian_diag(&self, p: &[f64], _y: &[f64], h: &mut [f64]) {
        let n = p.len() as f64;
        h[..p.len()].fill(n - 1.0);
    }

    fn hessian_vec(&self, p: &[f64], _y: &[f64], v: &[f64], out: &mut [f64]) {
        // H v = n·v − (Σv)·𝟙  (here H_{ii}=n−1, H_{ij}=−1)
        let n = p.len() as f64;
        let sv: f64 = v.iter().sum();
        for i in 0..v.len() {
            out[i] = n * v[i] - sv;
        }
    }

    fn diagonal_hessian(&self) -> bool {
        false
    }
}

/// All Table-2 losses by name (CLI / config lookup).
pub fn loss_by_name(name: &str) -> Option<Box<dyn Loss>> {
    match name {
        "ridge" => Some(Box::new(RidgeLoss)),
        "l1svm" | "hinge" => Some(Box::new(L1SvmLoss)),
        "l2svm" | "squared_hinge" => Some(Box::new(L2SvmLoss)),
        "logistic" => Some(Box::new(LogisticLoss)),
        "rankrls" => Some(Box::new(RankRlsLoss)),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    /// Central finite-difference gradient check.
    fn check_gradient(loss: &dyn Loss, p: &[f64], y: &[f64], tol: f64) {
        let n = p.len();
        let mut g = vec![0.0; n];
        loss.gradient(p, y, &mut g);
        let eps = 1e-6;
        for i in 0..n {
            let mut pp = p.to_vec();
            pp[i] += eps;
            let up = loss.value(&pp, y);
            pp[i] -= 2.0 * eps;
            let dn = loss.value(&pp, y);
            let fd = (up - dn) / (2.0 * eps);
            assert!(
                (g[i] - fd).abs() < tol * (1.0 + fd.abs()),
                "{} grad[{i}]: {} vs fd {}",
                loss.name(),
                g[i],
                fd
            );
        }
    }

    /// Finite-difference Hessian-vector check (for twice-differentiable
    /// points).
    fn check_hessian_vec(loss: &dyn Loss, p: &[f64], y: &[f64], tol: f64) {
        let n = p.len();
        let mut rng = Pcg32::seeded(7);
        let v = rng.normal_vec(n);
        let mut hv = vec![0.0; n];
        loss.hessian_vec(p, y, &v, &mut hv);
        let eps = 1e-6;
        let mut p_up = p.to_vec();
        let mut p_dn = p.to_vec();
        for i in 0..n {
            p_up[i] += eps * v[i];
            p_dn[i] -= eps * v[i];
        }
        let mut g_up = vec![0.0; n];
        let mut g_dn = vec![0.0; n];
        loss.gradient(&p_up, y, &mut g_up);
        loss.gradient(&p_dn, y, &mut g_dn);
        for i in 0..n {
            let fd = (g_up[i] - g_dn[i]) / (2.0 * eps);
            assert!(
                (hv[i] - fd).abs() < tol * (1.0 + fd.abs()),
                "{} Hv[{i}]: {} vs fd {}",
                loss.name(),
                hv[i],
                fd
            );
        }
    }

    fn labels(n: usize, rng: &mut Pcg32) -> Vec<f64> {
        (0..n).map(|_| if rng.bernoulli(0.5) { 1.0 } else { -1.0 }).collect()
    }

    #[test]
    fn gradients_match_finite_differences() {
        let mut rng = Pcg32::seeded(100);
        let n = 12;
        // Keep predictions away from hinge kinks (p·y = 1).
        let p: Vec<f64> = (0..n).map(|_| rng.normal() * 2.0 + 0.01).collect();
        let y = labels(n, &mut rng);
        for loss in
            [&RidgeLoss as &dyn Loss, &L2SvmLoss, &LogisticLoss, &RankRlsLoss, &L1SvmLoss]
        {
            // skip points too near a kink for hinge losses
            let safe = p
                .iter()
                .zip(&y)
                .all(|(pi, yi)| (pi * yi - 1.0).abs() > 1e-3);
            if safe || loss.diagonal_hessian() && loss.name() == "ridge" {
                check_gradient(loss, &p, &y, 1e-4);
            }
        }
    }

    #[test]
    fn hessians_match_finite_differences() {
        let mut rng = Pcg32::seeded(101);
        let n = 10;
        let p: Vec<f64> = (0..n).map(|_| rng.normal() * 2.0 + 0.013).collect();
        let y = labels(n, &mut rng);
        let safe = p.iter().zip(&y).all(|(pi, yi)| (pi * yi - 1.0).abs() > 1e-3);
        assert!(safe, "test setup landed on a kink; change seed");
        for loss in [&RidgeLoss as &dyn Loss, &L2SvmLoss, &LogisticLoss, &RankRlsLoss] {
            check_hessian_vec(loss, &p, &y, 1e-4);
        }
    }

    #[test]
    fn l2svm_zero_loss_region() {
        let p = vec![2.0, -3.0];
        let y = vec![1.0, -1.0];
        let loss = L2SvmLoss;
        assert_eq!(loss.value(&p, &y), 0.0);
        let mut g = vec![9.0; 2];
        loss.gradient(&p, &y, &mut g);
        assert_eq!(g, vec![0.0, 0.0]);
        assert_eq!(L2SvmLoss::active_mask(&p, &y), vec![0.0, 0.0]);
    }

    #[test]
    fn l2svm_active_mask_matches_hessian() {
        let mut rng = Pcg32::seeded(102);
        let n = 20;
        let p = rng.normal_vec(n);
        let y = labels(n, &mut rng);
        let mask = L2SvmLoss::active_mask(&p, &y);
        let mut h = vec![0.0; n];
        L2SvmLoss.hessian_diag(&p, &y, &mut h);
        assert_eq!(mask, h);
    }

    #[test]
    fn rankrls_value_matches_double_sum() {
        let mut rng = Pcg32::seeded(103);
        let n = 8;
        let p = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        let mut brute = 0.0;
        for i in 0..n {
            for j in 0..n {
                let d = y[i] - p[i] - y[j] + p[j];
                brute += d * d;
            }
        }
        brute *= 0.25;
        // our closed form counts each unordered pair twice, like the paper's ¼ΣΣ
        assert!((RankRlsLoss.value(&p, &y) - brute).abs() < 1e-9);
    }

    #[test]
    fn rankrls_is_translation_invariant() {
        let mut rng = Pcg32::seeded(104);
        let n = 9;
        let p = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        let shifted: Vec<f64> = p.iter().map(|v| v + 5.0).collect();
        assert!((RankRlsLoss.value(&p, &y) - RankRlsLoss.value(&shifted, &y)).abs() < 1e-8);
    }

    #[test]
    fn logistic_is_stable_at_extremes() {
        let loss = LogisticLoss;
        let v = loss.value(&[1000.0, -1000.0], &[-1.0, 1.0]);
        assert!(v.is_finite());
        assert!((v - 2000.0).abs() < 1e-6);
        let v2 = loss.value(&[1000.0, -1000.0], &[1.0, -1.0]);
        assert!(v2.abs() < 1e-12);
    }

    #[test]
    fn loss_lookup() {
        for name in ["ridge", "l1svm", "l2svm", "logistic", "rankrls", "hinge"] {
            assert!(loss_by_name(name).is_some(), "{name}");
        }
        assert!(loss_by_name("nope").is_none());
    }
}
